"""Triangular-solve + rank-1 Cholesky-update kernels vs jax.scipy oracles.

Covers the sparse-posterior kernel stack end to end: the Pallas blocked
forward-substitution kernel (both orientations through the ops.py flip
trick), the O(m^2) column-sweep cholupdate against a fresh-factorization
oracle, padding neutrality (lane/block padding must never change values),
and compile-count pins for the jitted entry points.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.tri_solve import (
    BLOCK_K,
    LANE,
    cholupdate_pallas,
    tri_solve_pallas,
)

RNG = np.random.RandomState(17)


def _chol_factor(m, seed=0):
    """A well-conditioned random lower-triangular factor."""
    rng = np.random.RandomState(seed)
    A = rng.randn(m, m).astype(np.float32)
    K = A @ A.T + m * np.eye(m, dtype=np.float32)
    return np.linalg.cholesky(K).astype(np.float32)


# ---------------------------------------------------------------------------
# tri-solve vs the jax.scipy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k", [(5, 3), (8, 1), (64, 64), (130, 7),
                                 (128, 256), (256, 300)])
@pytest.mark.parametrize("trans", [False, True])
def test_tri_solve_sweep(m, k, trans):
    L = _chol_factor(m, seed=m + k)
    b = RNG.randn(m, k).astype(np.float32)
    want = np.asarray(ref.tri_solve(jnp.asarray(L), jnp.asarray(b),
                                    trans=trans))
    got = np.asarray(ops.tri_solve(jnp.asarray(L), jnp.asarray(b),
                                   trans=trans, impl="pallas_interpret"))
    assert got.shape == (m, k)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_tri_solve_vector_rhs():
    """(m,) right-hand sides round-trip through the (m, 1) kernel shape."""
    L = _chol_factor(40, seed=2)
    b = RNG.randn(40).astype(np.float32)
    for trans in (False, True):
        want = np.asarray(ref.tri_solve(jnp.asarray(L), jnp.asarray(b),
                                        trans=trans))
        got = np.asarray(ops.tri_solve(jnp.asarray(L), jnp.asarray(b),
                                       trans=trans, impl="pallas_interpret"))
        assert got.shape == (40,)
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


@given(st.integers(min_value=2, max_value=40),
       st.integers(min_value=1, max_value=20),
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=15, deadline=None)
def test_tri_solve_solves_system_property(m, k, seed):
    """Property: L @ x reproduces b (checked against the system, not just
    another solver) for random sizes and factors."""
    L = _chol_factor(m, seed=seed)
    b = np.random.RandomState(seed + 1).randn(m, k).astype(np.float32)
    x = np.asarray(ops.tri_solve(jnp.asarray(L), jnp.asarray(b),
                                 impl="pallas_interpret"))
    np.testing.assert_allclose(L @ x, b, atol=5e-4, rtol=5e-4)
    xt = np.asarray(ops.tri_solve(jnp.asarray(L), jnp.asarray(b),
                                  trans=True, impl="pallas_interpret"))
    np.testing.assert_allclose(L.T @ xt, b, atol=5e-4, rtol=5e-4)


def test_tri_solve_padding_neutrality():
    """m exactly at / just past the LANE boundary and k at / past BLOCK_K:
    padding must be value-neutral, not just shape-correct."""
    for m in (LANE - 1, LANE, LANE + 1):
        for k in (BLOCK_K - 1, BLOCK_K, BLOCK_K + 1):
            L = _chol_factor(m, seed=m)
            b = np.random.RandomState(k).randn(m, k).astype(np.float32)
            want = np.asarray(ref.tri_solve(jnp.asarray(L), jnp.asarray(b)))
            got = np.asarray(tri_solve_pallas(jnp.asarray(L), jnp.asarray(b),
                                              interpret=True))
            np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# cholupdate vs the fresh-factorization oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [3, 8, 64, 130, 256])
def test_cholupdate_sweep(m):
    L = _chol_factor(m, seed=m)
    v = RNG.randn(m).astype(np.float32)
    oracle = np.linalg.cholesky(
        L @ L.T + np.outer(v, v) + 1e-6 * np.eye(m)).astype(np.float32)
    got = np.asarray(ops.cholupdate(jnp.asarray(L), jnp.asarray(v),
                                    impl="pallas_interpret"))
    np.testing.assert_allclose(got, oracle, atol=2e-3, rtol=2e-3)
    # result is lower-triangular with positive diagonal
    np.testing.assert_allclose(got, np.tril(got), atol=1e-6)
    assert (np.diag(got) > 0).all()


@given(st.integers(min_value=2, max_value=48),
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=15, deadline=None)
def test_cholupdate_reconstructs_updated_gram_property(m, seed):
    """Property: got @ got.T == L L^T + v v^T for random sizes/updates."""
    L = _chol_factor(m, seed=seed)
    v = np.random.RandomState(seed + 3).randn(m).astype(np.float32)
    got = np.asarray(ops.cholupdate(jnp.asarray(L), jnp.asarray(v),
                                    impl="pallas_interpret"))
    np.testing.assert_allclose(got @ got.T, L @ L.T + np.outer(v, v),
                               atol=5e-3, rtol=5e-3)


def test_cholupdate_xla_matches_pallas():
    """The two dispatch rungs agree (the XLA scan is the CPU default)."""
    L = _chol_factor(33, seed=5)
    v = RNG.randn(33).astype(np.float32)
    xla = np.asarray(ops.cholupdate(jnp.asarray(L), jnp.asarray(v),
                                    impl="xla"))
    pal = np.asarray(ops.cholupdate(jnp.asarray(L), jnp.asarray(v),
                                    impl="pallas_interpret"))
    np.testing.assert_allclose(xla, pal, atol=1e-4, rtol=1e-4)


def test_cholupdate_padding_neutrality():
    for m in (LANE - 1, LANE, LANE + 1):
        L = _chol_factor(m, seed=m)
        v = np.random.RandomState(m).randn(m).astype(np.float32)
        want = np.asarray(ref.cholupdate(jnp.asarray(L), jnp.asarray(v)))
        got = np.asarray(cholupdate_pallas(jnp.asarray(L), jnp.asarray(v),
                                           interpret=True))
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# compile-count pins: one compile per kernel across shape-stable callers
# ---------------------------------------------------------------------------


def test_tri_solve_single_compile_across_flip_orientations():
    """Forward and transposed solves share ONE compiled kernel (the flip
    trick feeds the transposed case through the same (m, k) signature)."""
    m, k = 64, 32
    L = jnp.asarray(_chol_factor(m, seed=9))
    b = jnp.asarray(RNG.randn(m, k).astype(np.float32))
    before = tri_solve_pallas._cache_size()
    ops.tri_solve(L, b, impl="pallas_interpret")
    ops.tri_solve(L, b, trans=True, impl="pallas_interpret")
    ops.tri_solve(L, b + 1.0, impl="pallas_interpret")
    assert tri_solve_pallas._cache_size() - before <= 1


def test_cholupdate_single_compile_across_repeat_updates():
    m = 96
    L = jnp.asarray(_chol_factor(m, seed=4))
    before = cholupdate_pallas._cache_size()
    out = L
    for i in range(3):
        v = jnp.asarray(np.random.RandomState(i).randn(m).astype(np.float32))
        out = ops.cholupdate(out, v, impl="pallas_interpret")
    assert cholupdate_pallas._cache_size() - before <= 1
