"""archlint analyzer + runtime lock-order witness tests.

Three layers:

1. Fixture tests per pass/rule — known-bad snippets are flagged at the right
   file:line, known-good snippets (every blessed pattern in the tree:
   bucket-padded jit wrappers, study-lock-guarded RMW, code-consulting
   handlers, cv.wait on the held CV) stay clean.
2. Runtime witness semantics — inverted two-lock order fails, consistent
   order passes, RLock reentrancy records no edge, Condition delegation.
3. Pinned regressions for the real defects the passes surfaced (ISSUE 9):
   SetStudyState / UpdateMetadata RMW under the study lock, early-stop and
   remote batch-suggest preserving carried status codes, dispatch
   duck-typing ``.code``, and the restructured work-queue lease loop.
"""

import subprocess
import textwrap
import threading
import time
from pathlib import Path

import pytest

from archlint import (
    chaos_pass,
    core,
    error_pass,
    lock_pass,
    retrace_pass,
    schema_pass,
)
from repro.core import StudyState
from repro.core.metadata import MetadataDelta
from repro.service import InMemoryDatastore, VizierClient, VizierService
from repro.service import _lockwitness as lw
from repro.service.pythia_service import PythiaServicer
from repro.service.rpc import Servicer, StatusCode, VizierRpcError
from repro.service.vizier_service import InProcessPythia
from repro.service.work_queue import ShardedWorkQueue

REPO_ROOT = Path(__file__).resolve().parents[1]


def _src(tmp_path: Path, rel: str, code: str) -> core.SourceFile:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return core.SourceFile.load(p, tmp_path)


def _line_of(src: core.SourceFile, needle: str) -> int:
    for i, line in enumerate(src.lines, start=1):
        if needle in line:
            return i
    raise AssertionError(f"{needle!r} not in fixture")


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Lock-discipline pass
# ---------------------------------------------------------------------------


def test_lock_order_cycle_flagged(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class S:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._b:
                    with self._a:
                        pass
        """)
    findings = lock_pass.run([src])
    assert lock_pass.RULE_ORDER in _rules(findings)


def test_lock_order_consistent_is_clean(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class S:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._a:
                    with self._b:
                        pass
        """)
    assert lock_pass.run([src]) == []


def test_nonreentrant_self_reacquire_is_a_cycle(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class T:
            def __init__(self):
                self._l = threading.Lock()

            def outer(self):
                with self._l:
                    self.inner()

            def inner(self):
                with self._l:
                    pass
        """)
    findings = lock_pass.run([src])
    assert lock_pass.RULE_ORDER in _rules(findings)


def test_sibling_subclasses_get_no_phantom_cross_edges(tmp_path):
    # Pins the receiver-context-sensitive dispatch: self._locked_write()
    # reached through super().save() resolves to exactly the receiver's
    # implementation. Context-insensitive resolution created a phantom
    # Mem._lock -> Sql._lock cycle between the two datastore backends.
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class Base:
            def save(self):
                self._locked_write()

            def _locked_write(self):
                raise NotImplementedError

        class Mem(Base):
            def __init__(self):
                self._lock = threading.RLock()

            def _locked_write(self):
                with self._lock:
                    pass

            def batch(self):
                with self._lock:
                    super().save()

        class Sql(Base):
            def __init__(self):
                self._lock = threading.RLock()

            def _locked_write(self):
                with self._lock:
                    pass

            def batch(self):
                with self._lock:
                    super().save()
        """)
    findings = lock_pass.run([src])
    assert lock_pass.RULE_ORDER not in _rules(findings)


def test_blocking_calls_under_lock_flagged(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import logging
        import threading
        import time

        log = logging.getLogger(__name__)

        class S:
            def __init__(self):
                self._l = threading.Lock()

            def direct(self):
                with self._l:
                    time.sleep(0.1)

            def logs(self):
                with self._l:
                    log.warning("held")

            def fine(self):
                time.sleep(0.1)
                with self._l:
                    pass
                log.warning("released")
        """)
    findings = [f for f in lock_pass.run([src])
                if f.rule == lock_pass.RULE_BLOCKING]
    lines = {f.line for f in findings}
    assert _line_of(src, "time.sleep(0.1)") in lines  # first occurrence: direct
    assert _line_of(src, 'log.warning("held")') in lines
    assert _line_of(src, 'log.warning("released")') not in lines


def test_blocking_reached_interprocedurally(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        import time

        class C:
            def __init__(self):
                self._l = threading.Lock()

            def a(self):
                with self._l:
                    self.b()

            def b(self):
                time.sleep(1)
        """)
    findings = [f for f in lock_pass.run([src])
                if f.rule == lock_pass.RULE_BLOCKING]
    assert findings, "sleep reached through self.b() under the lock"
    assert findings[0].line == _line_of(src, "self.b()")


def test_cv_wait_on_held_cv_and_bounded_wait_are_clean(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class Q:
            def __init__(self):
                self._cv = threading.Condition()
                self._evt = threading.Event()

            def sanctioned(self):
                with self._cv:
                    self._cv.wait()

            def bounded(self):
                with self._cv:
                    self._evt.wait(1.0)
        """)
    assert lock_pass.run([src]) == []


def test_unbounded_foreign_wait_under_lock_flagged(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class Q:
            def __init__(self):
                self._cv = threading.Condition()
                self._evt = threading.Event()

            def bad(self):
                with self._cv:
                    self._evt.wait()
        """)
    findings = lock_pass.run([src])
    assert _rules(findings) == {lock_pass.RULE_BLOCKING}


def test_datastore_call_under_queue_lock_flagged(tmp_path):
    src = _src(tmp_path, "service/work_mod.py", """\
        import threading

        class WorkQueue:
            def __init__(self, ds: FooDatastore):
                self._cv = threading.Condition()
                self._ds = ds

            def bad(self, study):
                with self._cv:
                    self._ds.update_study(study)

            def fine(self, study):
                with self._cv:
                    pass
                self._ds.update_study(study)
        """)
    findings = [f for f in lock_pass.run([src])
                if f.rule == lock_pass.RULE_QUEUE_DS]
    assert [f.line for f in findings] == [
        _line_of(src, "self._ds.update_study(study)")]


def test_unguarded_study_write_flagged_and_blessed_patterns_clean(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading

        class Svc:
            def __init__(self, ds: FooDatastore):
                self._ds = ds
                self._locks = {}

            def _study_lock(self, name):
                return self._locks.setdefault(name, threading.Lock())

            def bad(self, study):
                self._ds.update_study(study)

            def guarded(self, study):
                with self._study_lock(study.name):
                    self._ds.update_study(study)

            def _apply_locked(self, study):
                self._ds.update_study(study)
        """)
    findings = [f for f in lock_pass.run([src])
                if f.rule == lock_pass.RULE_UNGUARDED]
    assert [f.line for f in findings] == [
        _line_of(src, "def bad(") + 1]


def test_witness_factories_count_as_locks(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import time
        from repro.service._lockwitness import make_lock

        class S:
            def __init__(self):
                self._l = make_lock("S._l")

            def bad(self):
                with self._l:
                    time.sleep(1)
        """)
    assert lock_pass.RULE_BLOCKING in _rules(lock_pass.run([src]))


# ---------------------------------------------------------------------------
# Retrace-hygiene pass
# ---------------------------------------------------------------------------


def test_host_sync_in_jit_body_flagged(tmp_path):
    src = _src(tmp_path, "pythia/mod.py", """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return float(x)

        @jax.jit
        def g(x):
            return x.item()

        @jax.jit
        def h(x):
            import numpy as np
            return np.asarray(x)
        """)
    findings = [f for f in retrace_pass.run([src])
                if f.rule == retrace_pass.RULE_HOST_SYNC]
    lines = {f.line for f in findings}
    assert _line_of(src, "return float(x)") in lines
    assert _line_of(src, "return x.item()") in lines
    assert _line_of(src, "return np.asarray(x)") in lines


def test_shape_derived_host_values_are_clean(tmp_path):
    src = _src(tmp_path, "pythia/mod.py", """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            n = float(x.shape[0])
            m = int(len(x))
            return x * n + m
        """)
    assert retrace_pass.run([src]) == []


def test_tracer_branch_flagged_and_static_exempt(tmp_path):
    src = _src(tmp_path, "pythia/mod.py", """\
        import functools
        import jax
        import jax.numpy as jnp

        @jax.jit
        def bad(x):
            if x > 0:
                return x
            return -x

        @functools.partial(jax.jit, static_argnames=("n",))
        def static_ok(x, n):
            if n > 2:
                return x * 2
            return x

        @jax.jit
        def none_ok(x, y=None):
            if y is None:
                return x
            return x + y

        @jax.jit
        def shape_ok(x):
            if x.shape[0] > 4:
                return x[:4]
            return x
        """)
    findings = retrace_pass.run([src])
    assert [(f.rule, f.line) for f in findings] == [
        (retrace_pass.RULE_TRACER_BRANCH, _line_of(src, "if x > 0:"))]


def test_jit_in_function_flagged_but_init_exempt(tmp_path):
    src = _src(tmp_path, "kernels/mod.py", """\
        import jax

        def per_call(f, x):
            return jax.jit(f)(x)

        class K:
            def __init__(self, f):
                self._f = jax.jit(f)
        """)
    findings = [f for f in retrace_pass.run([src])
                if f.rule == retrace_pass.RULE_JIT_IN_FN]
    assert [f.line for f in findings] == [_line_of(src, "return jax.jit(f)(x)")]


def test_unpadded_jit_entry_flagged_and_bucket_wrapper_clean(tmp_path):
    src = _src(tmp_path, "kernels/mod.py", """\
        import jax
        import jax.numpy as jnp

        def _impl(x):
            return x * 2

        kernel = jax.jit(_impl)

        def bad_call(xs):
            return kernel(jnp.array([v for v in xs]))

        def good_call(xs, pad_to_bucket):
            padded = pad_to_bucket(xs)
            return kernel(padded)
        """)
    findings = [f for f in retrace_pass.run([src])
                if f.rule == retrace_pass.RULE_UNPADDED]
    assert [f.line for f in findings] == [
        _line_of(src, "kernel(jnp.array([v for v in xs]))")]


def test_retrace_pass_scoped_to_pythia_and_kernels(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import jax

        @jax.jit
        def f(x):
            return float(x)
        """)
    assert retrace_pass.run([src]) == []


# ---------------------------------------------------------------------------
# Schema / namespace pass
# ---------------------------------------------------------------------------


def test_reserved_namespace_write_outside_whitelist_flagged(tmp_path):
    src = _src(tmp_path, "src/repro/service/foo.py",
               'NS = "repro.secret.blob"\n')
    findings = schema_pass.run([src], root=tmp_path, diff_base=None)
    assert [(f.rule, f.line) for f in findings] == [
        (schema_pass.RULE_NAMESPACE, 1)]


def test_reserved_namespace_whitelist_docstring_and_imports_clean(tmp_path):
    (tmp_path / "src/repro/configs").mkdir(parents=True)
    state = _src(tmp_path, "src/repro/pythia/state.py",
                 'NS = "repro.gp_bandit.state"\n')
    doc = _src(tmp_path, "src/repro/service/doc.py", '''\
        """Mentions repro.gp_bandit.state in prose only.

        The string "repro.anything.here" inside a docstring is documentation,
        not a write.
        """
        X = 1
        ''')
    imp = _src(tmp_path, "src/repro/service/imp.py",
               'MODULE = "repro.configs.base"\n')
    findings = schema_pass.run([state, doc, imp], root=tmp_path,
                               diff_base=None)
    assert findings == []


def _git(cwd, *args):
    subprocess.run(["git", "-c", "user.email=t@t", "-c", "user.name=t",
                    *args], cwd=cwd, check=True, capture_output=True)


STATE_V1 = """\
STATE_SCHEMA_VERSION = 1


class PolicyState:
    alpha: float
    beta: float
"""


@pytest.mark.parametrize("bumped", [False, True])
def test_schema_version_bump_is_diff_aware(tmp_path, bumped):
    rel = schema_pass.STATE_REL
    p = tmp_path / rel
    p.parent.mkdir(parents=True)
    p.write_text(STATE_V1)
    _git(tmp_path, "init", "-q")
    _git(tmp_path, "add", "-A")
    _git(tmp_path, "commit", "-q", "-m", "seed")
    version = 2 if bumped else 1
    p.write_text(STATE_V1.replace("STATE_SCHEMA_VERSION = 1",
                                  f"STATE_SCHEMA_VERSION = {version}")
                 + "    gamma: float\n")
    src = core.SourceFile.load(p, tmp_path)
    findings = schema_pass.run([src], root=tmp_path, diff_base="HEAD")
    if bumped:
        assert findings == []
    else:
        assert [(f.rule, f.line) for f in findings] == [
            (schema_pass.RULE_VERSION, 1)]
        assert "gamma" in findings[0].message


def test_schema_version_check_skipped_without_diff_base(tmp_path):
    rel = schema_pass.STATE_REL
    p = tmp_path / rel
    p.parent.mkdir(parents=True)
    p.write_text(STATE_V1)
    src = core.SourceFile.load(p, tmp_path)
    assert schema_pass.run([src], root=tmp_path, diff_base=None) == []


# ---------------------------------------------------------------------------
# Error-discipline pass
# ---------------------------------------------------------------------------


def test_bare_and_baseexception_excepts_flagged(tmp_path):
    src = _src(tmp_path, "service/operations.py", """\
        class Runner:
            def run(self):
                try:
                    work()
                except:
                    pass

            def run2(self):
                try:
                    work()
                except BaseException:
                    pass

            def run3(self):
                try:
                    work()
                except ValueError:
                    pass
        """)
    findings = [f for f in error_pass.run([src])
                if f.rule == error_pass.RULE_BARE]
    assert [f.line for f in findings] == [
        _line_of(src, "except:"),
        _line_of(src, "except BaseException:")]


def test_swallowed_status_code_flagged_and_consulting_clean(tmp_path):
    src = _src(tmp_path, "service/vizier_service.py", """\
        class Svc:
            def RunBad(self, op):
                try:
                    work()
                except Exception as e:
                    op["error"] = {"code": StatusCode.INTERNAL}

            def RunGood(self, op):
                try:
                    work()
                except Exception as e:
                    code = getattr(e, "code", None)
                    if not isinstance(code, int):
                        code = StatusCode.INTERNAL
                    op["error"] = {"code": code}

            def RunFailOp(self, op):
                try:
                    work()
                except Exception as e:
                    self._fail_op(op, e)
        """)
    findings = [f for f in error_pass.run([src])
                if f.rule == error_pass.RULE_SWALLOW]
    assert [f.line for f in findings] == [
        _line_of(src, 'op["error"] = {"code": StatusCode.INTERNAL}')]


def test_unmapped_service_raise_flagged_and_carriers_exempt(tmp_path):
    src = _src(tmp_path, "service/vizier_service.py", """\
        class QuotaError(Exception):
            def __init__(self, msg):
                super().__init__(msg)
                self.code = 8

        class Svc:
            def GetStudy(self, params):
                raise KeyError(params["name"])

            def CreateStudy(self, params):
                raise VizierRpcError(5, "nope")

            def DeleteStudy(self, params):
                raise QuotaError("over quota")

            def ListStudies(self, params):
                raise NotImplementedError()

            def _helper(self):
                raise ValueError("internal helpers are not RPC surface")
        """)
    findings = [f for f in error_pass.run([src])
                if f.rule == error_pass.RULE_UNMAPPED]
    assert [f.line for f in findings] == [
        _line_of(src, 'raise KeyError(params["name"])')]
    assert "GetStudy" in findings[0].message


def test_error_pass_scoped_to_isolation_basenames(tmp_path):
    src = _src(tmp_path, "service/helpers.py", """\
        class H:
            def Run(self):
                try:
                    work()
                except:
                    pass
        """)
    assert error_pass.run([src]) == []


# ---------------------------------------------------------------------------
# Chaos-hook discipline pass
# ---------------------------------------------------------------------------


def test_chaos_inject_under_lock_flagged(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        from repro.service import chaos

        class S:
            def __init__(self):
                self._lock = threading.Lock()

            def f(self):
                with self._lock:
                    chaos.inject("datastore.write")
        """)
    findings = chaos_pass.run([src])
    assert _rules(findings) == {chaos_pass.RULE_UNDER_LOCK}
    assert findings[0].line == _line_of(src, 'chaos.inject("datastore.write")')


def test_chaos_inject_under_cv_and_imported_name_flagged(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        from repro.service.chaos import inject

        class Q:
            def __init__(self):
                self._cv = threading.Condition()

            def lease(self):
                with self._cv:
                    inject("queue.lease")
        """)
    findings = chaos_pass.run([src])
    assert _rules(findings) == {chaos_pass.RULE_UNDER_LOCK}


def test_chaos_inject_outside_lock_clean(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        from repro.service import chaos

        class S:
            def __init__(self):
                self._lock = threading.Lock()

            def f(self):
                granted = None
                with self._lock:
                    granted = object()
                chaos.inject("queue.lease", lease=granted)
                with open("x") as fh:
                    chaos.inject("transport.send")
        """)
    assert chaos_pass.run([src]) == []


def test_chaos_inject_in_nested_def_under_lock_clean(tmp_path):
    # A callback *defined* under the lock runs later, off the lock.
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        from repro.service import chaos

        class S:
            def __init__(self):
                self._lock = threading.Lock()

            def f(self):
                with self._lock:
                    def cb():
                        chaos.inject("worker.batch")
                    self._cb = cb
        """)
    assert chaos_pass.run([src]) == []


def test_chaos_ungated_hook_flagged_and_guarded_clean(tmp_path):
    bad = _src(tmp_path, "a/chaos.py", """\
        _injector = None

        def inject(site, **ctx):
            _injector.fire(site, ctx)
        """)
    findings = chaos_pass.run([bad])
    assert _rules(findings) == {chaos_pass.RULE_UNGATED}
    assert findings[0].line == _line_of(bad, "def inject")

    good = _src(tmp_path, "b/chaos.py", '''\
        _injector = None

        def inject(site, **ctx):
            """Docstring before the guard is fine."""
            if _injector is None:
                return
            _injector.fire(site, ctx)
        ''')
    assert chaos_pass.run([good]) == []


def test_chaos_pass_real_rpc_seams_are_suppressed_not_silent(tmp_path):
    """Non-vacuity pin: the two sanctioned transport-send seams in rpc.py DO
    trip the rule (so the pass watches them) and their standalone
    suppression comments cover every occurrence."""
    src = core.SourceFile.load(
        REPO_ROOT / "src/repro/service/rpc.py", REPO_ROOT)
    raw = chaos_pass.run([src])
    assert raw, "expected chaos-call-under-lock findings in rpc.py"
    assert _rules(raw) == {chaos_pass.RULE_UNDER_LOCK}
    assert core.filter_suppressed(raw, [src]) == []


def test_chaos_pass_repo_chaos_module_is_gated():
    src = core.SourceFile.load(
        REPO_ROOT / "src/repro/service/chaos.py", REPO_ROOT)
    assert chaos_pass.run([src]) == []


# ---------------------------------------------------------------------------
# Core: suppressions, baseline, runner
# ---------------------------------------------------------------------------


def test_same_line_suppression_with_reason(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        import time

        class S:
            def __init__(self):
                self._l = threading.Lock()

            def f(self):
                with self._l:
                    time.sleep(0.1)  # archlint: disable=lock-blocking-call test fixture
        """)
    findings = core.filter_suppressed(lock_pass.run([src]), [src])
    assert findings == []
    assert src.suppression_reason_findings() == []


def test_standalone_multiline_comment_suppression_covers_next_stmt(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        import time

        class S:
            def __init__(self):
                self._l = threading.Lock()

            def f(self):
                with self._l:
                    # archlint: disable=lock-blocking-call sanctioned because this
                    # fixture documents the multi-line reason idiom
                    time.sleep(0.1)
        """)
    assert core.filter_suppressed(lock_pass.run([src]), [src]) == []


def test_suppression_without_reason_is_itself_a_finding(tmp_path):
    src = _src(tmp_path, "service/mod.py",
               "X = 1  # archlint: disable=lock-blocking-call\n")
    findings = src.suppression_reason_findings()
    assert [(f.rule, f.line) for f in findings] == [
        (core.RULE_SUPPRESSION_NO_REASON, 1)]


def test_suppression_only_covers_named_rules(tmp_path):
    src = _src(tmp_path, "service/mod.py", """\
        import threading
        import time

        class S:
            def __init__(self):
                self._l = threading.Lock()

            def f(self):
                with self._l:
                    time.sleep(0.1)  # archlint: disable=jit-host-sync wrong rule
        """)
    findings = core.filter_suppressed(lock_pass.run([src]), [src])
    assert _rules(findings) == {lock_pass.RULE_BLOCKING}


def test_baseline_key_roundtrip(tmp_path):
    f = core.Finding("src/x.py", 42, "lock-order-cycle", "cycle: a -> b -> a")
    baseline = tmp_path / "baseline.txt"
    baseline.write_text("# comment line\n\n" + f.baseline_key() + "\n")
    keys = core.load_baseline(baseline)
    assert keys == {f.baseline_key()}
    # line numbers drift without invalidating the entry
    assert core.Finding("src/x.py", 99, f.rule, f.message).baseline_key() in keys
    assert core.load_baseline(tmp_path / "missing.txt") == set()


def test_analyze_paths_reports_syntax_errors(tmp_path):
    p = tmp_path / "src/repro/service/broken.py"
    p.parent.mkdir(parents=True)
    p.write_text("def f(:\n")
    findings, _ = core.analyze_paths(tmp_path, [p], fast=True)
    assert [f.rule for f in findings] == [core.RULE_SYNTAX_ERROR]


def test_repo_tree_is_archlint_clean():
    """The PR's own acceptance gate: zero unsuppressed findings on the tree
    (the checked-in baseline stays empty)."""
    findings, _ = core.analyze_paths(REPO_ROOT, fast=False)
    baseline = core.load_baseline(REPO_ROOT / "tools/archlint/baseline.txt")
    new = [f.render() for f in findings if f.baseline_key() not in baseline]
    assert new == []


# ---------------------------------------------------------------------------
# Runtime lock-order witness
# ---------------------------------------------------------------------------


def test_witness_inverted_two_lock_order_fails():
    w = lw.LockWitness()
    a = lw._WitnessedLock(threading.Lock(), "A", w)
    b = lw._WitnessedLock(threading.Lock(), "B", w)
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    with pytest.raises(lw.LockOrderViolation) as e:
        w.assert_acyclic()
    assert set(e.value.cycle) == {"A", "B"}


def test_witness_consistent_order_is_acyclic():
    w = lw.LockWitness()
    a = lw._WitnessedLock(threading.Lock(), "A", w)
    b = lw._WitnessedLock(threading.Lock(), "B", w)
    for _ in range(3):
        with a:
            with b:
                pass
    assert w.edges() == {("A", "B")}
    w.assert_acyclic()


def test_witness_reentrant_reacquire_records_no_edge():
    w = lw.LockWitness()
    r = lw._WitnessedLock(threading.RLock(), "R", w, reentrant=True)
    other = lw._WitnessedLock(threading.Lock(), "O", w)
    with r:
        with other:
            with r:        # reentry with O interleaved: still no R-edge
                pass
    assert w.edges() == {("R", "O")}
    w.assert_acyclic()


def test_witness_nonreentrant_self_acquire_is_a_cycle():
    w = lw.LockWitness()
    l = lw._WitnessedLock(threading.Lock(), "L", w)
    l.acquire()
    l.acquire(blocking=False)   # would deadlock if blocking
    l.release()
    assert ("L", "L") in w.edges()
    with pytest.raises(lw.LockOrderViolation):
        w.assert_acyclic()


def test_witness_same_name_distinct_objects_is_the_study_lock_hazard():
    w = lw.LockWitness()
    s1 = lw._WitnessedLock(threading.Lock(), "study", w)
    s2 = lw._WitnessedLock(threading.Lock(), "study", w)
    with s1:
        with s2:
            pass
    with pytest.raises(lw.LockOrderViolation) as e:
        w.assert_acyclic()
    assert e.value.cycle == ["study"]


def test_witness_condition_delegation_supports_wait():
    # Condition probes _is_owned/_release_save/_acquire_restore on the lock;
    # __getattr__ delegation to the inner RLock must keep that working.
    w = lw.LockWitness()
    cv = threading.Condition(
        lw._WitnessedLock(threading.RLock(), "cv", w, reentrant=True))
    with cv:
        cv.wait(timeout=0.01)
    assert cv.acquire(blocking=False)
    cv.release()
    w.assert_acyclic()


def test_witness_factories_gate_on_env(monkeypatch):
    monkeypatch.delenv("ARCHLINT_WITNESS", raising=False)
    assert not lw.witness_enabled()
    assert not isinstance(lw.make_lock("x"), lw._WitnessedLock)
    assert not isinstance(lw.make_rlock("x"), lw._WitnessedLock)
    assert isinstance(lw.make_condition("x"), threading.Condition)

    monkeypatch.setenv("ARCHLINT_WITNESS", "1")
    assert lw.witness_enabled()
    assert isinstance(lw.make_lock("x"), lw._WitnessedLock)
    assert isinstance(lw.make_rlock("x"), lw._WitnessedLock)
    cv = lw.make_condition("x")
    assert isinstance(cv, threading.Condition)
    assert isinstance(cv._lock, lw._WitnessedLock)


def test_witness_reset_clears_edges():
    w = lw.LockWitness()
    a = lw._WitnessedLock(threading.Lock(), "A", w)
    b = lw._WitnessedLock(threading.Lock(), "B", w)
    with a:
        with b:
            pass
    assert w.edges()
    w.reset()
    assert w.edges() == set()


# ---------------------------------------------------------------------------
# Pinned regressions for defects the passes surfaced
# ---------------------------------------------------------------------------


def _make_local(ds):
    return VizierService(ds, InProcessPythia(ds))


def _assert_blocks_on_study_lock(svc, study_name, call):
    lock = svc._study_lock(study_name)
    assert lock.acquire(timeout=1.0)
    done = threading.Event()

    def runner():
        call()
        done.set()

    t = threading.Thread(target=runner, daemon=True)
    t.start()
    try:
        assert not done.wait(0.25), "handler ran without the study lock"
    finally:
        lock.release()
    assert done.wait(3.0), "handler never completed after lock release"
    t.join(timeout=1.0)


def test_set_study_state_takes_study_lock(basic_config):
    # Defect: SetStudyState did an unlocked read-modify-write; racing an
    # UpdateMetadata/_apply_delta_locked writer resurrected a stale study
    # snapshot (archlint unguarded-study-write).
    ds = InMemoryDatastore()
    svc = _make_local(ds)
    client = VizierClient.load_or_create_study(
        "lock-set", basic_config, client_id="c", target=svc)
    _assert_blocks_on_study_lock(
        svc, client.study_name,
        lambda: svc.SetStudyState(
            {"name": client.study_name, "state": StudyState.INACTIVE.value}))
    assert ds.get_study(client.study_name).state == StudyState.INACTIVE
    svc.shutdown()


def test_update_metadata_takes_study_lock(basic_config):
    ds = InMemoryDatastore()
    svc = _make_local(ds)
    client = VizierClient.load_or_create_study(
        "lock-md", basic_config, client_id="c", target=svc)
    delta = MetadataDelta()
    delta.assign("user", "k", "v")
    _assert_blocks_on_study_lock(
        svc, client.study_name,
        lambda: svc.UpdateMetadata(
            {"name": client.study_name, "delta": delta.to_proto()}))
    svc.shutdown()


def test_early_stop_failure_carries_invalid_argument(basic_config):
    # Defect: _run_early_stop_op collapsed every failure to INTERNAL, making
    # a permanent PolicyConstructionError (INVALID_ARGUMENT) look retryable
    # (archlint swallowed-status-code).
    ds = InMemoryDatastore()
    svc = _make_local(ds)
    client = VizierClient.load_or_create_study(
        "es-code", basic_config, client_id="c", target=svc)
    (trial,) = client.get_suggestions(count=1)
    study = ds.get_study(client.study_name)
    study.study_config.algorithm = "NO_SUCH_ALGORITHM"
    ds.update_study(study)
    op = svc.CheckTrialEarlyStoppingState(
        {"trial_name": f"{client.study_name}/trials/{trial.id}"})["operation"]
    deadline = time.time() + 5.0
    while not op.get("done") and time.time() < deadline:
        time.sleep(0.01)
        op = svc.GetOperation({"name": op["name"]})["operation"]
    assert op.get("done"), "early-stop op never completed"
    assert op["error"]["code"] == StatusCode.INVALID_ARGUMENT
    svc.shutdown()


class _CodedError(Exception):
    def __init__(self, code):
        super().__init__("carried")
        self.code = code


def _raise(e):
    raise e


def test_batch_suggest_preserves_carried_status_code():
    # Defect: PythiaBatchSuggest hard-coded INTERNAL per failed study, so the
    # remote topology retried permanent config errors the local path failed
    # fast (archlint swallowed-status-code).
    servicer = PythiaServicer("127.0.0.1:9")  # never dialed in this test
    servicer._load_many = lambda rpc, names: (
        {n: ("cfg", "desc", []) for n in names}, {})
    servicer._suggest_one = lambda rpc, entry, total, context: _raise(
        _CodedError(StatusCode.INVALID_ARGUMENT))
    resp = servicer.PythiaBatchSuggest(
        {"requests": [{"study_name": "s", "count": 1}]})
    assert resp["results"][0]["error"]["code"] == StatusCode.INVALID_ARGUMENT

    servicer._suggest_one = lambda rpc, entry, total, context: _raise(
        ValueError("no code attached"))
    resp = servicer.PythiaBatchSuggest(
        {"requests": [{"study_name": "s", "count": 1}]})
    assert resp["results"][0]["error"]["code"] == StatusCode.INTERNAL
    servicer.close()


def test_dispatch_duck_types_carried_code():
    svc = Servicer()
    svc.expose("Coded", lambda params: _raise(
        _CodedError(StatusCode.NOT_FOUND)))
    svc.expose("Plain", lambda params: _raise(ValueError("boom")))
    resp = svc.dispatch({"id": 1, "method": "Coded", "params": {}})
    assert not resp["ok"]
    assert resp["error"]["code"] == StatusCode.NOT_FOUND
    resp = svc.dispatch({"id": 2, "method": "Plain", "params": {}})
    assert resp["error"]["code"] == StatusCode.INTERNAL


def test_work_queue_lease_loop_still_reclaims_and_rejects_stale_ack():
    # Pins the lease() restructure (reclaim warnings now flush outside the
    # CV): expiry still requeues, the stale holder's ack is still a no-op.
    q = ShardedWorkQueue(n_shards=1, lease_timeout=0.05)
    q.enqueue({"study_name": "s", "name": "op1"})
    l1 = q.lease(worker_id=0, timeout=1.0)
    assert l1 is not None and [op["name"] for op in l1.ops] == ["op1"]
    time.sleep(0.08)
    l2 = q.lease(worker_id=1, timeout=1.0)
    assert l2 is not None and [op["name"] for op in l2.ops] == ["op1"]
    assert q.ack(l1) is False
    assert q.ack(l2) is True
    assert q.pending_count() == 0


def test_work_queue_lease_timeout_returns_none_promptly():
    q = ShardedWorkQueue(n_shards=1)
    t0 = time.monotonic()
    assert q.lease(worker_id=0, timeout=0.1) is None
    assert time.monotonic() - t0 < 1.0
    q.close()
    assert q.lease(worker_id=0, timeout=1.0) is None
