"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + no NaNs (required deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.models import build_model

pytestmark = pytest.mark.slow  # full-model tests; deselect with -m "not slow"


SMOKE_SHAPE = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_dummy_batch(SMOKE_SHAPE)
    (loss, metrics) = model.loss_fn(params, batch)
    assert np.isfinite(float(loss)), (arch_id, float(loss))
    logits, aux = model.forward(params, batch)
    if cfg.family == "vlm":
        expected_seq = SMOKE_SHAPE.seq_len  # img tokens + text
        assert logits.shape == (2, expected_seq, cfg.vocab_size)
    else:
        assert logits.shape == (2, SMOKE_SHAPE.seq_len, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id
    # gradient exists and is finite for every leaf
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat), arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_decode_step(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(batch=2, max_seq=16)
    tokens = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id


@pytest.mark.parametrize("arch_id", ["yi_34b", "zamba2_1p2b", "olmoe_1b_7b",
                                     "xlstm_350m", "deepseek_v2_236b"])
def test_decode_matches_prefill(arch_id):
    # float32: in bf16 the MoE router's near-tie top-k can flip an expert
    # between the prefill and decode paths (reduction-order noise), which is
    # a property of low-precision routing, not of the decode-path structure
    # this test checks.
    cfg = dataclasses.replace(
        get_arch(arch_id, reduced=True), param_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": tokens})
    cache = model.init_cache(batch=2, max_seq=16)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(params, cache, tokens[:, t : t + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    lf = logits_full.astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(lf - logits_dec.astype(jnp.float32)))
                / (jnp.max(jnp.abs(lf)) + 1e-9))
    assert rel < 0.05, (arch_id, rel)


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "yi_34b": (30e9, 40e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "deepseek_v2_236b": (200e9, 260e9),
        "stablelm_12b": (10e9, 14e9),
        "granite_20b": (18e9, 24e9),
        "phi4_mini_3p8b": (3e9, 4.8e9),
        "zamba2_1p2b": (0.9e9, 1.6e9),
        "xlstm_350m": (0.25e9, 0.5e9),
        "internvl2_76b": (60e9, 82e9),  # LLM backbone (frontend stubbed)
    }
    for arch_id, (lo, hi) in expect.items():
        n = get_arch(arch_id).param_count()
        assert lo <= n <= hi, f"{arch_id}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_capacity_drops_tokens():
    cfg = get_arch("olmoe_1b_7b", reduced=True)
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    model = build_model(tight)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_dummy_batch(SMOKE_SHAPE)
    loss, _ = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))  # drops are silent, not NaN


def test_training_reduces_loss_quick():
    """5 steps of adamw on the reduced zamba2 should reduce loss."""
    from repro.train.data import DataConfig
    from repro.train.step import TrainConfig, build_train_step, init_train_state
    from repro.train.data import make_dataset

    cfg = get_arch("zamba2_1p2b", reduced=True)
    model = build_model(cfg)
    tc = TrainConfig(peak_lr=3e-3, warmup_steps=1, total_steps=30)
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    step_fn = jax.jit(build_train_step(model, tc))
    ds = make_dataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                 global_batch=4))
    losses = []
    for i in range(8):
        state, metrics = step_fn(state, {k: jnp.asarray(v)
                                         for k, v in ds.batch_at(i % 2).items()})
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
