"""Loop-aware HLO cost model: validated against analytically-known programs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_costs import analyze_hlo
from repro.launch.hlo_analysis import parse_collectives, collective_summary


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    text = _compile_text(lambda x, y: x @ y, a, b)
    costs = analyze_hlo(text, 1)
    expected = 2 * 128 * 256 * 512
    assert abs(costs.flops - expected) / expected < 0.01


def test_scan_multiplies_flops():
    """A scanned matmul must count trip_count times (the cost_analysis bug
    this module exists to fix)."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    TRIPS = 12

    def scanned(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None

        out, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return out

    text = _compile_text(scanned, w, x)
    costs = analyze_hlo(text, 1)
    expected = TRIPS * 2 * 8 * 64 * 64
    assert abs(costs.flops - expected) / expected < 0.05, costs.flops
    # raw cost_analysis undercounts (sanity that the bug exists at all)
    ca = jax.jit(scanned).lower(w, x).compile().cost_analysis()
    if isinstance(ca, list):  # jax <= 0.4.x returns a one-element list
        ca = ca[0]
    assert ca["flops"] < expected / 2


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def nested(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    text = _compile_text(nested, w, x)
    costs = analyze_hlo(text, 1)
    expected = 12 * 2 * 8 * 32 * 32
    assert abs(costs.flops - expected) / expected < 0.05, costs.flops


def test_traffic_dus_counts_slice_not_buffer():
    big = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)
    small = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def upd(buf, row):
        return jax.lax.dynamic_update_slice(buf, row, (5, 0))

    # donated buffer -> true in-place update (how decode caches are lowered)
    text = jax.jit(upd, donate_argnums=(0,)).lower(big, small).compile().as_text()
    costs = analyze_hlo(text, 1)
    # must be ~2x the row (read+write), nowhere near the 16MB buffer
    assert costs.traffic_bytes < 1024 * 4 * 64, costs.traffic_bytes


def test_collective_parse_and_wire_model():
    hlo = """
HloModule test
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16] parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%p), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[16,16]{1,0} all-reduce(%p), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  ROOT %out = f32[16,16] add(%p, %p)
}
"""
    colls = parse_collectives(hlo, 8)
    summary = collective_summary(colls)
    assert summary["all-gather"]["count"] == 1
    ag_bytes = 64 * 16 * 4
    assert abs(summary["all-gather"]["wire_bytes"] - ag_bytes * 3 / 4) < 1
    ar_bytes = 16 * 16 * 4
    assert abs(summary["all-reduce"]["wire_bytes"] - 2 * ar_bytes * 7 / 8) < 1
