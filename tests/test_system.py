"""End-to-end behaviour tests for the paper's system.

The full loop: distributed topology (separate Pythia service over RPC),
GP-bandit algorithm, three parallel workers evaluating real (tiny) JAX
training jobs, one worker crash + rebind, early stopping enabled — i.e.
Figure 2 of the paper exercised in one test.
"""

import dataclasses
import threading

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (
    AutomatedStoppingConfig,
    ScaleType,
    StudyConfig,
    TrialState,
)
from repro.service import DistributedVizierServer, VizierClient
from repro.train.data import DataConfig
from repro.tuning import TuningTask, TuningWorker


pytestmark = pytest.mark.slow  # full-model tests; deselect with -m "not slow"


def test_full_system_distributed_tuning():
    server = DistributedVizierServer()
    try:
        config = StudyConfig()
        root = config.search_space.select_root()
        root.add_float_param("peak_lr", 1e-4, 1e-2, scale_type=ScaleType.LOG)
        root.add_float_param("weight_decay", 0.0, 0.2)
        config.metrics.add("loss", "MINIMIZE")
        config.algorithm = "GP_UCB"
        config.automated_stopping = (
            AutomatedStoppingConfig.median_automated_stopping_config(
                min_completed_trials=2))

        admin = VizierClient.load_or_create_study(
            "system-e2e", config, client_id="admin", target=server.address)

        arch = dataclasses.replace(
            get_arch("phi4_mini_3p8b", reduced=True),
            n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
            vocab_size=64, attn_q_chunk=32, attn_kv_chunk=32, remat="none")
        task = TuningTask(
            arch=arch,
            data=DataConfig(vocab_size=arch.vocab_size, seq_len=16,
                            global_batch=2),
            total_steps=6, report_every=3)

        # worker crash + rebind before the fleet starts
        w = TuningWorker(server.address, admin.study_name, "w0", task)
        (t_before,) = w.client.get_suggestions(count=1)
        del w  # crash
        w0 = TuningWorker(server.address, admin.study_name, "w0", task)
        (t_after,) = w0.client.get_suggestions(count=1)
        assert t_after.id == t_before.id

        workers = [w0] + [
            TuningWorker(server.address, admin.study_name, f"w{i}", task)
            for i in (1, 2)
        ]
        threads = [threading.Thread(target=wk.run, kwargs={"max_trials": 2})
                   for wk in workers]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=600)

        completed = admin.list_trials(states=[TrialState.COMPLETED])
        assert len(completed) >= 5
        assert all(np.isfinite(t.final_objective("loss")) for t in completed)
        assert {t.client_id for t in completed} >= {"w0", "w1", "w2"}
        assert all(len(t.measurements) >= 1 for t in completed)
        best = admin.list_optimal_trials()
        assert len(best) == 1
    finally:
        server.stop()
