"""Datastore contract tests (both implementations)."""

import threading

import pytest

from repro.core import Measurement, Metadata, StudyConfig, Trial, TrialState
from repro.core.study import Study
from repro.service.datastore import (
    InMemoryDatastore,
    KeyAlreadyExistsError,
    NotFoundError,
    SQLiteDatastore,
)


@pytest.fixture(params=["memory", "sqlite", "sqlite_file"])
def ds(request, tmp_path):
    if request.param == "memory":
        return InMemoryDatastore()
    if request.param == "sqlite":
        return SQLiteDatastore(":memory:")
    return SQLiteDatastore(str(tmp_path / "v.db"))


def make_study(name="owners/o/studies/s", basic_config=None) -> Study:
    cfg = basic_config or StudyConfig()
    if not cfg.metrics:
        cfg.search_space.select_root().add_float_param("x", 0, 1)
        cfg.metrics.add("m", "MAXIMIZE")
    return Study(name=name, display_name="s", study_config=cfg)


def test_study_crud(ds):
    s = make_study()
    assert ds.create_study(s) == s.name
    with pytest.raises(KeyAlreadyExistsError):
        ds.create_study(s)
    got = ds.get_study(s.name)
    assert got.name == s.name
    assert len(ds.list_studies("owners/o")) == 1
    assert ds.list_studies("owners/other") == []
    ds.delete_study(s.name)
    with pytest.raises(NotFoundError):
        ds.get_study(s.name)


def test_trial_sequential_ids_and_filters(ds):
    s = make_study()
    ds.create_study(s)
    for i in range(5):
        t = Trial(parameters={"x": i / 10}, client_id=f"c{i % 2}")
        created = ds.create_trial(s.name, t)
        assert created.id == i + 1
    t3 = ds.get_trial(s.name, 3)
    t3.complete(Measurement(metrics={"m": 0.5}))
    ds.update_trial(s.name, t3)
    assert len(ds.list_trials(s.name)) == 5
    assert [t.id for t in ds.list_trials(s.name, states=[TrialState.COMPLETED])] == [3]
    assert [t.id for t in ds.list_trials(s.name, client_id="c0")] == [1, 3, 5]
    assert [t.id for t in ds.list_trials(s.name, min_trial_id=4)] == [4, 5]
    assert ds.max_trial_id(s.name) == 5
    ds.delete_trial(s.name, 5)
    assert ds.max_trial_id(s.name) == 4


def test_metadata_updates(ds):
    s = make_study()
    ds.create_study(s)
    t = ds.create_trial(s.name, Trial(parameters={"x": 0.1}))
    md = Metadata()
    md.abs_ns("pythia")["state"] = "abc"
    ds.update_study_metadata(s.name, md)
    ds.update_trial_metadata(s.name, t.id, md)
    assert ds.get_study(s.name).study_config.metadata.abs_ns("pythia")["state"] == "abc"
    assert ds.get_trial(s.name, t.id).metadata.abs_ns("pythia")["state"] == "abc"


def test_operations(ds):
    s = make_study()
    ds.create_study(s)
    op = {"name": f"{s.name}/operations/1", "study_name": s.name,
          "client_id": "c", "done": False, "create_time": 1.0, "type": "suggest"}
    ds.put_operation(op)
    assert ds.get_operation(op["name"])["done"] is False
    assert len(ds.list_operations(s.name, only_pending=True)) == 1
    op["done"] = True
    ds.put_operation(op)
    assert ds.list_operations(s.name, only_pending=True) == []
    assert ds.get_operation(op["name"])["done"] is True


def test_sqlite_survives_reopen(tmp_path):
    path = str(tmp_path / "durable.db")
    ds1 = SQLiteDatastore(path)
    s = make_study()
    ds1.create_study(s)
    ds1.create_trial(s.name, Trial(parameters={"x": 0.5}))
    ds1.close()
    ds2 = SQLiteDatastore(path)  # "server restart"
    assert len(ds2.list_trials(s.name)) == 1
    ds2.close()


def test_list_trials_multi(ds):
    """One call fetches N studies' trials with state filtering."""
    names = []
    for i in range(3):
        s = make_study(name=f"owners/o/studies/m{i}")
        ds.create_study(s)
        names.append(s.name)
        for j in range(i + 1):
            t = ds.create_trial(s.name, Trial(parameters={"x": j / 10}))
            if j % 2 == 0:
                t.complete(Measurement(metrics={"m": 0.5}))
                ds.update_trial(s.name, t)

    out = ds.list_trials_multi(names)
    assert sorted(out) == sorted(names)
    assert [len(out[n]) for n in names] == [1, 2, 3]
    # per-study ordering by trial id
    assert all([t.id for t in v] == sorted(t.id for t in v) for v in out.values())

    completed = ds.list_trials_multi(names, states=[TrialState.COMPLETED])
    assert [len(completed[n]) for n in names] == [1, 1, 2]
    assert all(t.state == TrialState.COMPLETED
               for v in completed.values() for t in v)

    active = ds.list_trials_multi(names, states=[TrialState.ACTIVE])
    assert [len(active[n]) for n in names] == [0, 1, 1]

    assert ds.list_trials_multi([]) == {}


def test_list_trials_multi_missing_study(ds):
    s = make_study()
    ds.create_study(s)
    with pytest.raises(NotFoundError):
        ds.list_trials_multi([s.name, "owners/o/studies/ghost"])


def test_operation_crash_recovery(tmp_path):
    """Pending ops persisted by a crashed server complete after restart."""
    from repro.service.vizier_service import VizierService
    import repro.service.operations as ops_lib

    path = str(tmp_path / "crash.db")
    ds1 = SQLiteDatastore(path)
    svc1 = VizierService(ds1)
    s = make_study()
    ds1.create_study(s)
    # a suggest op persisted but never computed (server "crashes" first)
    op = ops_lib.new_suggest_operation(s.name, "cl", 1)
    ds1.put_operation(op)
    svc1.shutdown()
    ds1.close()

    ds2 = SQLiteDatastore(path)
    assert len(ds2.list_operations(s.name, only_pending=True)) == 1
    svc2 = VizierService(ds2)
    assert svc2.recover_pending_operations() == 1
    import time as _time

    deadline = _time.time() + 20
    while _time.time() < deadline:
        if ds2.get_operation(op["name"])["done"]:
            break
        _time.sleep(0.01)
    finished = ds2.get_operation(op["name"])
    assert finished["done"] and not finished.get("error"), finished
    assert len(finished["result"]["trials"]) == 1
    svc2.shutdown()
    ds2.close()


def test_concurrent_trial_creation(ds):
    s = make_study()
    ds.create_study(s)
    ids, errs = [], []
    lock = threading.Lock()

    def create(n):
        try:
            for _ in range(n):
                t = ds.create_trial(s.name, Trial(parameters={"x": 0.1}))
                with lock:
                    ids.append(t.id)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=create, args=(10,)) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert sorted(ids) == list(range(1, 41))  # unique sequential ids
