"""Datastore contract tests (both implementations)."""

import threading

import pytest

from repro.core import Measurement, Metadata, StudyConfig, Trial, TrialState
from repro.core.study import Study
from repro.service.datastore import (
    DatastoreBusyError,
    InMemoryDatastore,
    KeyAlreadyExistsError,
    NotFoundError,
    ShardedSqliteDatastore,
    SQLiteDatastore,
)


@pytest.fixture(params=["memory", "sqlite", "sqlite_file", "sharded"])
def ds(request, tmp_path):
    if request.param == "memory":
        return InMemoryDatastore()
    if request.param == "sqlite":
        return SQLiteDatastore(":memory:")
    if request.param == "sqlite_file":
        return SQLiteDatastore(str(tmp_path / "v.db"))
    return ShardedSqliteDatastore(str(tmp_path / "shards"), n_shards=4)


def make_study(name="owners/o/studies/s", basic_config=None) -> Study:
    cfg = basic_config or StudyConfig()
    if not cfg.metrics:
        cfg.search_space.select_root().add_float_param("x", 0, 1)
        cfg.metrics.add("m", "MAXIMIZE")
    return Study(name=name, display_name="s", study_config=cfg)


def test_study_crud(ds):
    s = make_study()
    assert ds.create_study(s) == s.name
    with pytest.raises(KeyAlreadyExistsError):
        ds.create_study(s)
    got = ds.get_study(s.name)
    assert got.name == s.name
    assert len(ds.list_studies("owners/o")) == 1
    assert ds.list_studies("owners/other") == []
    ds.delete_study(s.name)
    with pytest.raises(NotFoundError):
        ds.get_study(s.name)


def test_trial_sequential_ids_and_filters(ds):
    s = make_study()
    ds.create_study(s)
    for i in range(5):
        t = Trial(parameters={"x": i / 10}, client_id=f"c{i % 2}")
        created = ds.create_trial(s.name, t)
        assert created.id == i + 1
    t3 = ds.get_trial(s.name, 3)
    t3.complete(Measurement(metrics={"m": 0.5}))
    ds.update_trial(s.name, t3)
    assert len(ds.list_trials(s.name)) == 5
    assert [t.id for t in ds.list_trials(s.name, states=[TrialState.COMPLETED])] == [3]
    assert [t.id for t in ds.list_trials(s.name, client_id="c0")] == [1, 3, 5]
    assert [t.id for t in ds.list_trials(s.name, min_trial_id=4)] == [4, 5]
    assert ds.max_trial_id(s.name) == 5
    ds.delete_trial(s.name, 5)
    assert ds.max_trial_id(s.name) == 4


def test_metadata_updates(ds):
    s = make_study()
    ds.create_study(s)
    t = ds.create_trial(s.name, Trial(parameters={"x": 0.1}))
    md = Metadata()
    md.abs_ns("pythia")["state"] = "abc"
    ds.update_study_metadata(s.name, md)
    ds.update_trial_metadata(s.name, t.id, md)
    assert ds.get_study(s.name).study_config.metadata.abs_ns("pythia")["state"] == "abc"
    assert ds.get_trial(s.name, t.id).metadata.abs_ns("pythia")["state"] == "abc"


def test_operations(ds):
    s = make_study()
    ds.create_study(s)
    op = {"name": f"{s.name}/operations/1", "study_name": s.name,
          "client_id": "c", "done": False, "create_time": 1.0, "type": "suggest"}
    ds.put_operation(op)
    assert ds.get_operation(op["name"])["done"] is False
    assert len(ds.list_operations(s.name, only_pending=True)) == 1
    op["done"] = True
    ds.put_operation(op)
    assert ds.list_operations(s.name, only_pending=True) == []
    assert ds.get_operation(op["name"])["done"] is True


def test_sqlite_survives_reopen(tmp_path):
    path = str(tmp_path / "durable.db")
    ds1 = SQLiteDatastore(path)
    s = make_study()
    ds1.create_study(s)
    ds1.create_trial(s.name, Trial(parameters={"x": 0.5}))
    ds1.close()
    ds2 = SQLiteDatastore(path)  # "server restart"
    assert len(ds2.list_trials(s.name)) == 1
    ds2.close()


def test_list_trials_multi(ds):
    """One call fetches N studies' trials with state filtering."""
    names = []
    for i in range(3):
        s = make_study(name=f"owners/o/studies/m{i}")
        ds.create_study(s)
        names.append(s.name)
        for j in range(i + 1):
            t = ds.create_trial(s.name, Trial(parameters={"x": j / 10}))
            if j % 2 == 0:
                t.complete(Measurement(metrics={"m": 0.5}))
                ds.update_trial(s.name, t)

    out = ds.list_trials_multi(names)
    assert sorted(out) == sorted(names)
    assert [len(out[n]) for n in names] == [1, 2, 3]
    # per-study ordering by trial id
    assert all([t.id for t in v] == sorted(t.id for t in v) for v in out.values())

    completed = ds.list_trials_multi(names, states=[TrialState.COMPLETED])
    assert [len(completed[n]) for n in names] == [1, 1, 2]
    assert all(t.state == TrialState.COMPLETED
               for v in completed.values() for t in v)

    active = ds.list_trials_multi(names, states=[TrialState.ACTIVE])
    assert [len(active[n]) for n in names] == [0, 1, 1]

    assert ds.list_trials_multi([]) == {}


def test_list_trials_multi_missing_study(ds):
    s = make_study()
    ds.create_study(s)
    with pytest.raises(NotFoundError):
        ds.list_trials_multi([s.name, "owners/o/studies/ghost"])


def test_operation_crash_recovery(tmp_path):
    """Pending ops persisted by a crashed server complete after restart."""
    from repro.service.vizier_service import VizierService
    import repro.service.operations as ops_lib

    path = str(tmp_path / "crash.db")
    ds1 = SQLiteDatastore(path)
    svc1 = VizierService(ds1)
    s = make_study()
    ds1.create_study(s)
    # a suggest op persisted but never computed (server "crashes" first)
    op = ops_lib.new_suggest_operation(s.name, "cl", 1)
    ds1.put_operation(op)
    svc1.shutdown()
    ds1.close()

    ds2 = SQLiteDatastore(path)
    assert len(ds2.list_operations(s.name, only_pending=True)) == 1
    svc2 = VizierService(ds2)
    assert svc2.recover_pending_operations() == 1
    import time as _time

    deadline = _time.time() + 20
    while _time.time() < deadline:
        if ds2.get_operation(op["name"])["done"]:
            break
        _time.sleep(0.01)
    finished = ds2.get_operation(op["name"])
    assert finished["done"] and not finished.get("error"), finished
    assert len(finished["result"]["trials"]) == 1
    svc2.shutdown()
    ds2.close()


def test_concurrent_trial_creation(ds):
    s = make_study()
    ds.create_study(s)
    ids, errs = [], []
    lock = threading.Lock()

    def create(n):
        try:
            for _ in range(n):
                t = ds.create_trial(s.name, Trial(parameters={"x": 0.1}))
                with lock:
                    ids.append(t.id)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=create, args=(10,)) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert sorted(ids) == list(range(1, 41))  # unique sequential ids


# ---------------------------------------------------------------------------
# Transactions + busy handling (ISSUE 10 S2)
# ---------------------------------------------------------------------------


def test_study_transaction_rolls_back_partial_writes(tmp_path):
    ds = SQLiteDatastore(str(tmp_path / "txn.db"))
    s = make_study()
    ds.create_study(s)
    with pytest.raises(RuntimeError):
        with ds.study_transaction(s.name):  # reentrant: inner writes nest
            ds.create_trial(s.name, Trial(parameters={"x": 0.1}))
            ds.put_operation({"name": f"{s.name}/operations/a",
                              "done": False})
            raise RuntimeError("crash mid-write-set")
    # nothing of the torn write set is visible
    assert ds.list_trials(s.name) == []
    with pytest.raises(NotFoundError):
        ds.get_operation(f"{s.name}/operations/a")
    # and the store is fully usable afterwards (no stuck transaction)
    t = ds.create_trial(s.name, Trial(parameters={"x": 0.2}))
    assert t.id == 1


def test_locked_database_maps_to_busy_error_not_operational_error(tmp_path):
    """Pinned: raw ``sqlite3.OperationalError: database is locked`` must
    never escape — cross-process writers see DatastoreBusyError carrying
    UNAVAILABLE so dispatch/retry machinery can act on it."""
    path = str(tmp_path / "busy.db")
    a = SQLiteDatastore(path)
    b = SQLiteDatastore(path, busy_timeout_ms=100)
    s = make_study()
    a.create_study(s)
    holder = a.study_transaction(s.name)
    holder.__enter__()  # A holds BEGIN IMMEDIATE across the whole block
    try:
        with pytest.raises(DatastoreBusyError) as ei:
            b.create_trial(s.name, Trial(parameters={"x": 0.1}))
        assert ei.value.code == 14  # StatusCode.UNAVAILABLE, duck-typed
    finally:
        holder.__exit__(None, None, None)
    # once A commits, B's writer goes through
    t = b.create_trial(s.name, Trial(parameters={"x": 0.2}))
    assert t.id == 1
    a.close()
    b.close()


def test_concurrent_cross_connection_writers_never_raw_locked(tmp_path):
    """Two datastore instances (two connections, as two processes would
    have) hammering one file: busy_timeout serializes them; no writer ever
    surfaces sqlite3.OperationalError."""
    import sqlite3

    path = str(tmp_path / "contend.db")
    stores = [SQLiteDatastore(path) for _ in range(2)]
    s = make_study()
    stores[0].create_study(s)
    errs = []

    def write(store, base):
        try:
            for i in range(25):
                store.put_operation({
                    "name": f"{s.name}/operations/w{base}-{i}",
                    "study_name": s.name, "done": False})
        except sqlite3.OperationalError as e:  # the bug being pinned
            errs.append(("raw", e))
        except DatastoreBusyError as e:
            errs.append(("busy", e))

    threads = [threading.Thread(target=write, args=(st, i))
               for i, st in enumerate(stores)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not [e for e in errs if e[0] == "raw"], errs
    assert not errs, errs  # 10s busy budget: everyone lands
    assert len(stores[0].list_operations(s.name, only_pending=True)) == 50
    for st in stores:
        st.close()


def test_synchronous_mode_validated(tmp_path):
    with pytest.raises(ValueError):
        SQLiteDatastore(str(tmp_path / "x.db"), synchronous="TURBO")
    SQLiteDatastore(str(tmp_path / "y.db"), synchronous="FULL").close()


# ---------------------------------------------------------------------------
# Sharded backend specifics
# ---------------------------------------------------------------------------


def _sharded(tmp_path, n_shards=4, **kw):
    import os

    return ShardedSqliteDatastore(
        str(tmp_path / "sharddir"), n_shards=n_shards, **kw)


def test_sharded_file_layout_and_routing(tmp_path):
    import json
    import os

    from repro.service.operations import shard_of

    sds = _sharded(tmp_path, n_shards=4)
    names = [f"owners/o/studies/s{i}" for i in range(8)]
    for n in names:
        sds.create_study(make_study(n))
        sds.create_trial(n, Trial(parameters={"x": 0.5}))
    root = str(tmp_path / "sharddir")
    files = sorted(os.listdir(root))
    assert "layout.json" in files
    assert json.load(open(os.path.join(root, "layout.json")))["n_shards"] == 4
    shard_files = [f for f in files if f.startswith("shard-")
                   and f.endswith(".sqlite3")]
    assert shard_files == [f"shard-{i:02d}.sqlite3" for i in range(4)]
    # each study's rows live in exactly the shard shard_of() names
    for n in names:
        sid = shard_of(n, 4)
        assert sds._shards[sid].get_study(n).name == n
        for other in range(4):
            if other != sid:
                with pytest.raises(NotFoundError):
                    sds._shards[other].get_study(n)
    assert len(sds.list_studies("owners/o")) == 8
    sds.close()


def test_sharded_reopen_adopts_disk_layout(tmp_path):
    sds = _sharded(tmp_path, n_shards=4)
    s = make_study("owners/o/studies/persist")
    sds.create_study(s)
    sds.create_trial(s.name, Trial(parameters={"x": 0.3}))
    sds.put_operation({"name": f"{s.name}/operations/op1", "done": False})
    sds.close()
    # reopened with a DIFFERENT shard count: the on-disk layout wins, so
    # existing rows keep resolving to the right shard file
    re = _sharded(tmp_path, n_shards=8)
    assert len(re._shards) == 4
    assert re.get_study(s.name).name == s.name
    assert len(re.list_trials(s.name)) == 1
    assert re.get_operation(f"{s.name}/operations/op1")["done"] is False
    re.close()


def test_sharded_multi_reports_first_missing_in_request_order(tmp_path):
    sds = _sharded(tmp_path, n_shards=4)
    from repro.service.operations import shard_of

    present = "owners/o/studies/here"
    sds.create_study(make_study(present))
    # two missing studies on two different shards; the error must name the
    # FIRST one in request order regardless of shard iteration order
    missing = [f"owners/o/studies/ghost{i}" for i in range(8)]
    ghosts = sorted(missing, key=lambda n: -shard_of(n, 4))[:2]
    with pytest.raises(NotFoundError) as ei:
        sds.list_trials_multi([present, ghosts[0], ghosts[1]])
    assert ghosts[0] in str(ei.value)
    sds.close()


def test_sharded_get_operation_malformed_name_scans_all_shards(tmp_path):
    sds = _sharded(tmp_path, n_shards=4)
    with pytest.raises(NotFoundError):
        sds.get_operation("not-an-operation-name")
    sds.close()


def test_sharded_survives_reopen_after_hard_close(tmp_path):
    """The sharded analog of test_sqlite_survives_reopen: WAL + txn writes
    are readable by a fresh instance without any shutdown handshake."""
    sds = _sharded(tmp_path, n_shards=4)
    s = make_study("owners/o/studies/wal")
    sds.create_study(s)
    for i in range(5):
        sds.create_trial(s.name, Trial(parameters={"x": i / 10}))
    # NO close(): simulate the process dying with connections open
    re = _sharded(tmp_path, n_shards=4)
    assert [t.id for t in re.list_trials(s.name)] == [1, 2, 3, 4, 5]
    re.close()
    sds.close()
