"""Search space: unit mapping, scaling, conditionals, proto roundtrip.

Property tests (hypothesis) cover the core invariants:
  * from_unit(u) is always feasible; to_unit(from_unit(u)) ~ u for DOUBLEs
  * samples always validate
  * proto roundtrips are exact
"""

import math
import random

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    ParameterConfig,
    ParameterDict,
    ParameterType,
    ParameterValue,
    ScaleType,
    SearchSpace,
    lehmer_decode,
    subset_decode,
)


@st.composite
def double_configs(draw):
    lo = draw(st.floats(min_value=1e-6, max_value=1e3, allow_nan=False))
    hi = lo * draw(st.floats(min_value=1.0 + 1e-6, max_value=1e4))
    scale = draw(st.sampled_from([ScaleType.LINEAR, ScaleType.LOG,
                                  ScaleType.REVERSE_LOG, None]))
    return ParameterConfig("x", ParameterType.DOUBLE, bounds=(lo, hi),
                           scale_type=scale)


@given(double_configs(), st.floats(min_value=0, max_value=1))
@settings(max_examples=200, deadline=None)
def test_unit_roundtrip_double(cfg, u):
    v = cfg.from_unit(u)
    assert cfg.contains(v), (cfg.scale_type, u, v)
    u2 = cfg.to_unit(v)
    assert math.isclose(u, u2, abs_tol=1e-6), (cfg.scale_type, u, u2)


@given(st.integers(min_value=-50, max_value=50), st.integers(min_value=0, max_value=100),
       st.floats(min_value=0, max_value=1))
@settings(max_examples=100, deadline=None)
def test_unit_integer_feasible(lo, span, u):
    cfg = ParameterConfig("n", ParameterType.INTEGER, bounds=(lo, lo + span))
    v = cfg.from_unit(u)
    assert cfg.contains(v)
    assert isinstance(v.value, int)


@given(st.lists(st.floats(min_value=-1e3, max_value=1e3, allow_nan=False),
                min_size=1, max_size=10, unique=True),
       st.floats(min_value=0, max_value=1))
@settings(max_examples=100, deadline=None)
def test_discrete_from_unit_feasible(values, u):
    cfg = ParameterConfig("d", ParameterType.DISCRETE, feasible_values=values)
    v = cfg.from_unit(u)
    assert cfg.contains(v)


def test_log_scaling_shape():
    cfg = ParameterConfig("lr", ParameterType.DOUBLE, bounds=(1e-3, 10.0),
                          scale_type=ScaleType.LOG)
    # log scaling: geometric midpoint at u=0.5
    assert math.isclose(cfg.from_unit(0.5).as_float, 0.1, rel_tol=1e-6)
    assert math.isclose(cfg.to_unit(ParameterValue(0.1)), 0.5, abs_tol=1e-9)


def test_validation_errors():
    with pytest.raises(ValueError):
        ParameterConfig("x", ParameterType.DOUBLE, bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        ParameterConfig("x", ParameterType.DOUBLE, bounds=(-1.0, 1.0),
                        scale_type=ScaleType.LOG)  # log needs positive domain
    with pytest.raises(ValueError):
        ParameterConfig("x", ParameterType.CATEGORICAL, categories=["a", "a"])
    with pytest.raises(ValueError):
        ParameterConfig("x", ParameterType.INTEGER, bounds=(0, 10),
                        default_value=11)


@pytest.mark.parametrize("scale", [ScaleType.LOG, ScaleType.REVERSE_LOG,
                                   ScaleType.LINEAR])
def test_categorical_with_scale_type_raises_clean_valueerror(scale):
    """Regression: a CATEGORICAL config with a scale_type used to crash with
    TypeError (min() over feasible_values=None in the LOG-domain check)
    before reaching the intended ValueError. The check order is now fixed."""
    with pytest.raises(ValueError, match="cannot have a scale_type"):
        ParameterConfig("act", ParameterType.CATEGORICAL,
                        categories=["relu", "gelu"], scale_type=scale)


@given(st.one_of(
    st.floats(min_value=-1e9, max_value=1e9, allow_nan=False,
              allow_infinity=False),
    st.integers(min_value=-10**9, max_value=10**9),
    st.text(max_size=20),
))
@settings(max_examples=200, deadline=None)
def test_parameter_value_proto_roundtrip_preserves_type(v):
    """Regression: integral DOUBLE values used to demote to int through the
    wire (3.0 -> 3), so as_dict() returned a different type than was set.
    (Bools are excluded: they serialize as "true"/"false" strings by design.)"""
    back = ParameterValue.from_proto(ParameterValue(v).to_proto())
    assert back.value == v
    assert type(back.value) is type(v)


def test_conditional_activation(conditional_config):
    space = conditional_config.search_space
    p = ParameterDict.from_dict({"model": "dnn", "num_layers": 3, "dropout": 0.1})
    space.validate_parameters(p)
    # forest params under dnn assignment must be rejected
    bad = ParameterDict.from_dict({"model": "dnn", "num_trees": 50,
                                   "num_layers": 3, "dropout": 0.1})
    with pytest.raises(ValueError):
        space.validate_parameters(bad)
    # missing active child
    missing = ParameterDict.from_dict({"model": "dnn", "num_layers": 2})
    with pytest.raises(ValueError):
        space.validate_parameters(missing)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=100, deadline=None)
def test_conditional_sampling_always_valid(seed):
    space = SearchSpace()
    root = space.select_root()
    m = root.add_categorical_param("m", ["a", "b"])
    m.select_values(["a"]).add_float_param("fa", 0, 1)
    m.select_values(["b"]).add_int_param("ib", 0, 5)
    params = space.sample(random.Random(seed))
    space.validate_parameters(params)
    assert ("fa" in params) == (params["m"].as_str == "a")
    assert ("ib" in params) == (params["m"].as_str == "b")


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=50, deadline=None)
def test_space_proto_roundtrip(seed):
    space = SearchSpace()
    root = space.select_root()
    root.add_float_param("lr", 1e-4, 1e-1, scale_type=ScaleType.LOG)
    root.add_discrete_param("bs", [16, 32, 64])
    cat = root.add_categorical_param("opt", ["adam", "sgd"], default_value="adam")
    cat.select_values(["sgd"]).add_float_param("momentum", 0.0, 0.99)
    proto = space.to_proto()
    space2 = SearchSpace.from_proto(proto)
    assert space2.to_proto() == proto
    params = space2.sample(random.Random(seed))
    space.validate_parameters(params)


# -- combinatorial reparameterization (paper Appendix A.1.1) ----------------


@given(st.integers(min_value=1, max_value=8), st.integers(min_value=0, max_value=10**6))
@settings(max_examples=100, deadline=None)
def test_lehmer_decode_is_permutation(n, seed):
    rng = random.Random(seed)
    code = [rng.randrange(n - i) for i in range(n)]
    perm = lehmer_decode(code)
    assert sorted(perm) == list(range(n))


@given(st.integers(min_value=2, max_value=10), st.integers(min_value=0, max_value=10**6))
@settings(max_examples=100, deadline=None)
def test_subset_decode(n, seed):
    rng = random.Random(seed)
    k = rng.randint(1, n)
    code = [rng.randrange(n - i) for i in range(k)]
    sub = subset_decode(code, n)
    assert len(set(sub)) == k and all(0 <= s < n for s in sub)
