"""Figure-2 topology: standalone Pythia service over real sockets.

Covers the coalesced PythiaBatchSuggest dispatch (frame counts, in-process
equivalence), the fault-tolerance claims (Pythia killed and restarted
mid-batch, dropped call_many connections), and cross-study error isolation.
"""

import threading
import time

import pytest

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.service import (
    DistributedVizierServer,
    DefaultVizierServer,
    VizierBatchClient,
    VizierClient,
)
from repro.service.client import OperationFailedError
from repro.service.pythia_service import PythiaServicer
from repro.service.rpc import (
    RpcClient,
    RpcServer,
    StatusCode,
    VizierRpcError,
)
from repro.service.vizier_service import RemotePythia


def _config(algorithm: str = "RANDOM_SEARCH") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = algorithm
    return cfg


def _seed_deterministic(target, name, n=6, algorithm="GP_UCB"):
    """Create a study with bit-identical pre-evaluated trials on any server."""
    client = VizierClient.load_or_create_study(
        name, _config(algorithm), client_id="seeder", target=target)
    for i in range(n):
        x = (i + 1) / (n + 1.0)
        t = Trial(parameters={"x": x, "y": ((i * 3) % 7) / 7.0})
        t.complete(Measurement(metrics={"obj": -(x - 0.4) ** 2}))
        client.add_trial(t)
    return client


@pytest.fixture
def dist_server():
    s = DistributedVizierServer()
    yield s
    s.stop()


# ---------------------------------------------------------------------------
# Frame-counting regressions: the whole point of the coalesced dispatch
# ---------------------------------------------------------------------------


def test_batch_is_one_frame_per_hop(dist_server):
    """One BatchSuggestTrials -> exactly ONE PythiaBatchSuggest frame to the
    Pythia service and ONE GetTrialsMulti frame back to the API server,
    regardless of how many studies are in the batch."""
    names = []
    for i in range(3):
        c = VizierClient.load_or_create_study(
            f"frames-{i}", _config(), client_id="seed", target=dist_server.address)
        names.append(c.study_name)
        c.close()
    dist_server.servicer.reset_method_counts()
    dist_server.pythia_servicer.reset_method_counts()

    batch = VizierBatchClient(dist_server.address)
    results = batch.get_suggestions(
        [{"study_name": n, "client_id": f"w{i}"} for i, n in enumerate(names)])
    assert [len(r) for r in results] == [1, 1, 1]

    pythia_counts = dist_server.pythia_servicer.method_counts()
    api_counts = dist_server.servicer.method_counts()
    assert pythia_counts.get("PythiaBatchSuggest") == 1
    assert "PythiaSuggest" not in pythia_counts
    assert api_counts.get("GetTrialsMulti") == 1
    # the policies never re-RPC for data the prefetch already holds:
    # configs ride the GetTrialsMulti frame, trial reads hit the snapshot,
    # metadata writes are folded into the batch response
    assert "ListTrials" not in api_counts
    assert "GetStudy" not in api_counts
    assert "UpdateMetadata" not in api_counts
    batch.close()


def test_single_suggest_no_double_fetch(dist_server):
    """Regression for PythiaServicer._load: one PythiaSuggest used to issue a
    ListTrials for max_trial_id AND let the supporter re-fetch the same
    trials; now one GetTrialsMulti feeds both."""
    c = VizierClient.load_or_create_study(
        "single-fetch", _config(), client_id="w0", target=dist_server.address)
    dist_server.servicer.reset_method_counts()
    (t,) = c.get_suggestions(count=1)
    assert t.id >= 1
    api_counts = dist_server.servicer.method_counts()
    assert api_counts.get("GetTrialsMulti") == 1
    assert "GetStudy" not in api_counts  # config rides the same frame
    assert "ListTrials" not in api_counts
    c.close()


# ---------------------------------------------------------------------------
# Semantics of the coalesced remote dispatch
# ---------------------------------------------------------------------------


def test_remote_batch_coalesces_same_study(dist_server):
    """Two clients on one study: the summed count reaches the policy once."""
    c = VizierClient.load_or_create_study(
        "rsame", _config(), client_id="seed", target=dist_server.address)
    batch = VizierBatchClient(dist_server.address)
    results = batch.get_suggestions([
        {"study_name": c.study_name, "client_id": "a", "count": 2},
        {"study_name": c.study_name, "client_id": "b", "count": 1},
    ])
    assert [len(r) for r in results] == [2, 1]
    ids = [t.id for trials in results for t in trials]
    assert len(set(ids)) == 3, ids
    assert {t.client_id for t in results[0]} == {"a"}
    assert {t.client_id for t in results[1]} == {"b"}
    batch.close()
    c.close()


def test_remote_matches_in_process_trial_for_trial():
    """Same deterministic datastore state -> the Figure-2 split suggests
    exactly what the in-process InProcessPythia run suggests, per trial."""
    remote = DistributedVizierServer()
    local = DefaultVizierServer()
    try:
        names = []
        for target in (remote.address, local.address):
            for i in range(3):
                c = _seed_deterministic(target, f"equiv-{i}")
                if target == remote.address:
                    names.append(c.study_name)
                c.close()
        out = {}
        for target in (remote.address, local.address):
            batch = VizierBatchClient(target)
            results = batch.get_suggestions(
                [{"study_name": n, "client_id": f"w{i}", "count": 2}
                 for i, n in enumerate(names)])
            out[target] = [
                [t.parameters.as_dict() for t in trials] for trials in results
            ]
            batch.close()
        assert out[remote.address] == out[local.address]
    finally:
        remote.stop()
        local.stop()


def test_remote_bad_study_isolated(dist_server):
    """A sub-request whose policy cannot be built fails alone — no error
    leaks into its siblings' suggestions across the remote dispatch."""
    keep = VizierClient.load_or_create_study(
        "iso-keep", _config(), client_id="w", target=dist_server.address)
    doomed = VizierClient.load_or_create_study(
        "iso-doomed", _config(), client_id="w", target=dist_server.address)
    # corrupt the doomed study's algorithm after creation: the API server's
    # op-creation checks pass, the remote policy construction cannot
    study = dist_server.datastore.get_study(doomed.study_name)
    study.study_config.algorithm = "NO_SUCH_ALGORITHM"
    dist_server.datastore.update_study(study)

    batch = VizierBatchClient(dist_server.address)
    with pytest.raises(OperationFailedError) as ei:
        batch.get_suggestions([
            {"study_name": keep.study_name, "client_id": "w"},
            {"study_name": doomed.study_name, "client_id": "w"},
        ])
    assert "NO_SUCH_ALGORITHM" in str(ei.value)
    # the doomed op failed with the remote error attached
    ops = dist_server.datastore.list_operations(doomed.study_name)
    assert len(ops) == 1 and ops[0]["done"]
    assert "unknown algorithm" in ops[0]["error"]["message"]
    # the sibling completed with a real suggestion
    keep_ops = dist_server.datastore.list_operations(keep.study_name)
    assert len(keep_ops) == 1 and keep_ops[0]["done"]
    assert keep_ops[0]["error"] is None
    assert len(keep_ops[0]["result"]["trials"]) == 1
    batch.close()
    keep.close()


def test_pythia_batch_coalesces_duplicate_study_subrequests(dist_server):
    """Direct PythiaBatchSuggest with the same study twice: ONE policy
    invocation with the summed count, split across the sub-requests — a
    deterministic policy invoked twice on the identical snapshot would
    hand both clients duplicate points."""
    c = _seed_deterministic(dist_server.address, "pbs-dup")
    rpc = RpcClient(dist_server.pythia_address)
    result = rpc.call("PythiaBatchSuggest", {"requests": [
        {"study_name": c.study_name, "count": 2, "client_id": "a"},
        {"study_name": c.study_name, "count": 1, "client_id": "b"},
    ]})
    first, second = result["results"]
    assert len(first["suggestions"]) == 2
    assert len(second["suggestions"]) == 1
    params = [
        tuple(sorted(Trial.from_proto(p).parameters.as_dict().items()))
        for p in first["suggestions"] + second["suggestions"]
    ]
    assert len(set(params)) == 3, params
    # the study's metadata delta rides the group's first entry only
    from repro.core.metadata import MetadataDelta

    assert MetadataDelta.from_proto(second["metadata_delta"]).empty()
    rpc.close()
    c.close()


def test_pythia_batch_unknown_study_not_found(dist_server):
    """Direct PythiaBatchSuggest: an unknown study yields a NOT_FOUND error
    entry while its siblings' suggestions come back normally, and that code
    survives into a failed operation via fail_operation_from_exception."""
    c = VizierClient.load_or_create_study(
        "pbs-known", _config(), client_id="w", target=dist_server.address)
    rpc = RpcClient(dist_server.pythia_address)
    result = rpc.call("PythiaBatchSuggest", {"requests": [
        {"study_name": c.study_name, "count": 2, "client_id": "w"},
        {"study_name": "owners/x/studies/nope", "count": 1, "client_id": "w"},
    ]})
    ok, bad = result["results"]
    assert len(ok["suggestions"]) == 2 and "error" not in ok
    assert bad["error"]["code"] == StatusCode.NOT_FOUND

    import repro.service.operations as ops_lib

    op = ops_lib.new_suggest_operation(c.study_name, "w", 1)
    failed = ops_lib.fail_operation_from_exception(
        op, VizierRpcError(bad["error"]["code"], bad["error"]["message"]))
    assert failed["error"]["code"] == StatusCode.NOT_FOUND
    rpc.close()
    c.close()


def test_old_pythia_binary_fallback():
    """A Pythia server without PythiaBatchSuggest (pre-batch binary) still
    serves batched clients through the per-study shim."""

    class OldPythiaServicer(PythiaServicer):
        def __init__(self, target):
            super().__init__(target)
            del self._methods["PythiaBatchSuggest"]

    api = DefaultVizierServer()
    old_pythia = RpcServer(OldPythiaServicer(api.address)).start()
    api.servicer._pythia = RemotePythia(RpcClient(old_pythia.address))
    try:
        names = []
        for i in range(2):
            c = VizierClient.load_or_create_study(
                f"old-{i}", _config(), client_id="seed", target=api.address)
            names.append(c.study_name)
            c.close()
        batch = VizierBatchClient(api.address)
        results = batch.get_suggestions(
            [{"study_name": n, "client_id": f"w{i}"} for i, n in enumerate(names)])
        assert [len(r) for r in results] == [1, 1]
        batch.close()
    finally:
        old_pythia.stop()
        api.stop()


# ---------------------------------------------------------------------------
# Persisted algorithm state on the Figure-2 split (paper §6.3)
# ---------------------------------------------------------------------------


def test_stateless_policies_never_write_gp_state_namespace(dist_server):
    """RANDOM_SEARCH and CMA-ES must never touch the reserved
    ``repro.gp_bandit`` namespace — it belongs to the GP-bandit alone."""
    from repro.pythia.state import GP_BANDIT_NAMESPACE

    for i, algorithm in enumerate(("RANDOM_SEARCH", "CMA_ES")):
        c = VizierClient.load_or_create_study(
            f"stateless-{i}", _config(algorithm), client_id="w",
            target=dist_server.address)
        for r in range(3):
            (t,) = c.get_suggestions(count=1)
            c.complete_trial({"obj": 0.1 * r}, trial_id=t.id)
        md = dist_server.datastore.get_study(c.study_name).study_config.metadata
        namespaces = {ns.encode() for ns in md.namespaces()}
        assert not any(ns.startswith(GP_BANDIT_NAMESPACE) for ns in namespaces), (
            algorithm, namespaces)
        # the designer wrappers persist under their own namespace instead
        assert any(ns.startswith("pythia.designer_state") for ns in namespaces)
        c.close()


@pytest.mark.dist
def test_warm_state_survives_pythia_restart(dist_server):
    """Warm-start state lives in the API server's datastore, not the Pythia
    process: kill and revive Pythia between operations and the next fit must
    still resume from the persisted checkpoint."""
    from repro.core.metadata import Namespace
    from repro.pythia.state import GP_BANDIT_NAMESPACE, STATE_KEY, PolicyState

    c = _seed_deterministic(dist_server.address, "restart-state")

    def stored_state():
        md = dist_server.datastore.get_study(c.study_name).study_config.metadata
        blob = md.abs_ns(Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
        assert blob is not None
        return PolicyState.from_value(blob)

    (t1,) = c.get_suggestions(count=1)
    assert not stored_state().warm_started  # first fit is cold
    c.complete_trial({"obj": 0.11}, trial_id=t1.id)

    dist_server.stop_pythia()
    dist_server.restart_pythia()

    (t2,) = c.get_suggestions(count=1)
    state = stored_state()
    assert state.warm_started  # the fresh Pythia process resumed the fit
    assert state.num_trials == 7  # 6 seeded + 1 completed
    assert t2.id != t1.id
    c.close()


# ---------------------------------------------------------------------------
# Fault injection (paper: the Figure-2 split "remains fully fault-tolerant")
# ---------------------------------------------------------------------------


@pytest.mark.dist
def test_pythia_killed_and_restarted_mid_batch(dist_server):
    """Kill the Pythia service between op creation and dispatch; restart it
    while the RemotePythia client is inside its retry/backoff loop. The
    pending operations must complete without client-visible errors."""
    names = []
    for i in range(2):
        c = VizierClient.load_or_create_study(
            f"kill-{i}", _config(), client_id="seed", target=dist_server.address)
        names.append(c.study_name)
        c.close()

    dist_server.stop_pythia()

    def revive():
        time.sleep(0.5)  # inside the RPC client's backoff window
        dist_server.restart_pythia()

    reviver = threading.Thread(target=revive)
    reviver.start()
    batch = VizierBatchClient(dist_server.address)
    results = batch.get_suggestions(
        [{"study_name": n, "client_id": f"w{i}"} for i, n in enumerate(names)],
        timeout=60.0)
    reviver.join()
    assert [len(r) for r in results] == [1, 1]
    assert all(t.id >= 1 for trials in results for t in trials)
    batch.close()


@pytest.mark.dist
def test_recovered_op_rides_out_pythia_outage(dist_server):
    """Crash recovery meets the Figure-2 split: a pending op re-launched by
    recover_pending_operations() while Pythia is DOWN burns UNAVAILABLE
    retries until the service is revived, then completes without error."""
    c = VizierClient.load_or_create_study(
        "outage", _config(), client_id="w", target=dist_server.address)
    # Enqueue a pending suggest op directly (as if the server crashed after
    # persisting it but before the Pythia dispatch ran) — with Pythia dead.
    import repro.service.operations as ops_lib

    op = ops_lib.new_suggest_operation(c.study_name, "w2", 1)
    dist_server.datastore.put_operation(op)
    dist_server.stop_pythia()
    n = dist_server.servicer.recover_pending_operations()
    assert n >= 1
    time.sleep(1.0)  # let the dispatch burn a few UNAVAILABLE retries
    assert not dist_server.datastore.get_operation(op["name"])["done"]
    dist_server.restart_pythia()
    deadline = time.time() + 30
    while time.time() < deadline:
        if dist_server.datastore.get_operation(op["name"])["done"]:
            break
        time.sleep(0.02)
    done = dist_server.datastore.get_operation(op["name"])
    assert done["done"] and done["error"] is None
    assert len(done["result"]["trials"]) == 1
    c.close()


@pytest.mark.dist
def test_call_many_survives_dropped_connection():
    """Drop the TCP connection under call_many (server restarted between
    batches): the pipelined batch retries transparently on the new socket."""
    api = DefaultVizierServer()
    address = api.address
    client = RpcClient(address)
    assert len(client.call_many("Ping", [{} for _ in range(4)])) == 4

    # Restart the RPC server on the same port: the client's pooled socket is
    # now a dead peer, so the next call_many hits a transport error first.
    host, port = address.rsplit(":", 1)
    api._server.stop()
    api._server = RpcServer(api.servicer, host=host, port=int(port)).start()

    results = client.call_many("Ping", [{} for _ in range(4)])
    assert len(results) == 4 and all("time" in r for r in results)
    client.close()
    api.stop()


def test_call_many_return_exceptions_isolation():
    """Per-item application errors come back in-place, frame-aligned."""
    api = DefaultVizierServer()
    client = RpcClient(api.address)
    c = VizierClient.load_or_create_study(
        "cmre", _config(), client_id="w", target=api.address)
    results = client.call_many(
        "GetStudy",
        [{"name": c.study_name}, {"name": "owners/x/studies/nope"},
         {"name": c.study_name}],
        return_exceptions=True,
    )
    assert results[0]["study"]["name"] == c.study_name
    assert isinstance(results[1], VizierRpcError)
    assert results[1].code == StatusCode.NOT_FOUND
    assert results[2]["study"]["name"] == c.study_name
    c.close()
    client.close()
    api.stop()


# ---------------------------------------------------------------------------
# Cross-process end-to-end (real sockets, many clients) — slow
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.dist
def test_end_to_end_three_studies_batched_clients():
    """3 studies x concurrent batched clients against the full Figure-2
    split; every suggestion matches the in-process run trial-for-trial on
    the seeded deterministic policy."""
    remote = DistributedVizierServer()
    local = DefaultVizierServer()
    try:
        names = []
        for target in (remote.address, local.address):
            for i in range(3):
                c = _seed_deterministic(target, f"e2e-{i}")
                if target == remote.address:
                    names.append(c.study_name)
                c.close()

        def run_rounds(target):
            """3 rounds of batched suggest+complete across all studies."""
            batch = VizierBatchClient(target)
            seen = []
            for r in range(3):
                results = batch.get_suggestions(
                    [{"study_name": n, "client_id": f"w{i}", "count": 1}
                     for i, n in enumerate(names)])
                seen.append([
                    [t.parameters.as_dict() for t in trials]
                    for trials in results
                ])
                batch.complete_trials([
                    {"trial_name": f"{n}/trials/{trials[0].id}",
                     "metrics": {"obj": 0.25 + 0.1 * r}}
                    for n, trials in zip(names, results)
                ])
            batch.close()
            return seen

        assert run_rounds(remote.address) == run_rounds(local.address)

        # and concurrent batched clients on the remote topology stay sane
        errs = []

        def hammer(wid):
            try:
                batch = VizierBatchClient(remote.address)
                for r in range(2):
                    results = batch.get_suggestions(
                        [{"study_name": n, "client_id": f"h{wid}", "count": 1}
                         for n in names])
                    batch.complete_trials([
                        {"trial_name": f"{n}/trials/{trials[0].id}",
                         "metrics": {"obj": 0.1 * wid + 0.01 * r}}
                        for n, trials in zip(names, results)
                    ])
                batch.close()
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs, errs
    finally:
        remote.stop()
        local.stop()
