"""Batched suggestion pipeline: coalescing, re-binding, equivalence."""

import threading

import pytest

from repro.core import Measurement, ScaleType, StudyConfig
from repro.core.study import Study, TrialState
from repro.service import (
    DefaultVizierServer,
    VizierBatchClient,
    VizierClient,
)
from repro.service.client import BatchSuggestionError
from repro.service.datastore import InMemoryDatastore
from repro.service.rpc import RpcClient, RpcServer
from repro.service.vizier_service import InProcessPythia, VizierService


def _gp_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def _seed_study(target, name, n_completed=6, client_id="seeder"):
    """Create a study and complete n trials so GP_UCB leaves cold start."""
    client = VizierClient.load_or_create_study(
        name, _gp_config(), client_id=client_id, target=target)
    for i in range(n_completed):
        (t,) = client.get_suggestions(count=1)
        client.complete_trial({"obj": -(i / n_completed - 0.4) ** 2}, trial_id=t.id)
    return client


@pytest.fixture
def server():
    s = DefaultVizierServer()
    yield s
    s.stop()


def test_batch_coalesces_same_study(server):
    """Two clients on one study in one batch: distinct trials, one dispatch."""
    seed = _seed_study(server.address, "coalesce")
    batch = VizierBatchClient(server.address)
    results = batch.get_suggestions([
        {"study_name": seed.study_name, "client_id": "a", "count": 2},
        {"study_name": seed.study_name, "client_id": "b", "count": 1},
    ])
    assert [len(r) for r in results] == [2, 1]
    ids = [t.id for trials in results for t in trials]
    assert len(set(ids)) == 3, ids  # all distinct (coalesced, not duplicated)
    assert {t.client_id for t in results[0]} == {"a"}
    assert {t.client_id for t in results[1]} == {"b"}
    params = [
        (t.parameters["x"].as_float, t.parameters["y"].as_float)
        for trials in results for t in trials
    ]
    assert len(set(params)) == 3, params  # one policy call saw the full batch
    batch.close()
    seed.close()


def test_batch_multi_study(server):
    names = [
        _seed_study(server.address, f"multi-{i}").study_name for i in range(3)
    ]
    batch = VizierBatchClient(server.address)
    results = batch.get_suggestions(
        [{"study_name": n, "client_id": f"w{i}"} for i, n in enumerate(names)]
    )
    assert [len(r) for r in results] == [1, 1, 1]
    for i, trials in enumerate(results):
        assert trials[0].study_name == names[i]
    batch.close()


def test_batch_client_id_rebinding(server):
    """A crashed worker's ACTIVE trial comes back through the batched path."""
    seed = _seed_study(server.address, "rebind")
    worker = VizierClient(server.address, seed.study_name, "worker_7")
    (orig,) = worker.get_suggestions(count=1)  # worker "crashes" here

    batch = VizierBatchClient(server.address)
    (again,) = batch.get_suggestions(
        [{"study_name": seed.study_name, "client_id": "worker_7"}]
    )
    assert [t.id for t in again] == [orig.id]  # same trial, not a new one
    assert again[0].client_id == "worker_7"
    batch.close()
    worker.close()
    seed.close()


def test_batched_equals_sequential_on_fixed_seed():
    """Identical datastore state -> batched == sequential suggestions.

    GP_UCB is deterministic given the completed-trial set (its rng is seeded
    by the policy seed + trial count), so a batched dispatch over one study
    must produce exactly the suggestion the sequential path produces.
    """
    def build():
        from repro.core import Trial

        server = DefaultVizierServer()
        client = VizierClient.load_or_create_study(
            "equiv", _gp_config(), client_id="seeder", target=server.address)
        # deterministic pre-evaluated trials -> bit-identical datastore state
        for i in range(6):
            x = (i + 1) / 7.0
            t = Trial(parameters={"x": x, "y": ((i * 3) % 7) / 7.0})
            t.complete(Measurement(metrics={"obj": -(x - 0.4) ** 2}))
            client.add_trial(t)
        return server, client

    server_a, client_a = build()
    (seq,) = client_a.get_suggestions(count=1)

    server_b, _ = build()
    batch = VizierBatchClient(server_b.address)
    ((bat,),) = batch.get_suggestions(
        [{"study_name": client_a.study_name, "client_id": "seeder2"}]
    )
    assert seq.parameters.as_dict() == bat.parameters.as_dict()
    batch.close()
    server_a.stop()
    server_b.stop()


def test_batch_complete_trials_roundtrip(server):
    seed = _seed_study(server.address, "bct")
    batch = VizierBatchClient(server.address)
    (trials,) = batch.get_suggestions(
        [{"study_name": seed.study_name, "client_id": "w", "count": 2}]
    )
    done = batch.complete_trials([
        {"trial_name": f"{seed.study_name}/trials/{trials[0].id}",
         "metrics": {"obj": 0.9}},
        {"trial_name": f"{seed.study_name}/trials/{trials[1].id}",
         "infeasibility_reason": "nan loss"},
    ])
    assert done[0].state == TrialState.COMPLETED
    assert done[1].state == TrialState.INFEASIBLE
    batch.close()
    seed.close()


def test_batch_complete_partial_failure(server):
    seed = _seed_study(server.address, "bct-err")
    batch = VizierBatchClient(server.address)
    (trials,) = batch.get_suggestions(
        [{"study_name": seed.study_name, "client_id": "w"}]
    )
    done = batch.complete_trials([
        {"trial_name": f"{seed.study_name}/trials/99999", "metrics": {"obj": 1.0}},
        {"trial_name": f"{seed.study_name}/trials/{trials[0].id}",
         "metrics": {"obj": 0.5}},
    ])
    assert done[0] is None  # unknown trial fails alone
    assert done[1] is not None and done[1].state == TrialState.COMPLETED
    batch.close()
    seed.close()


def test_batch_unknown_study_isolated(server):
    """A bad sub-request errors without failing its siblings' operations —
    and the siblings' already-dispatched work is polled and surfaced on the
    exception instead of being orphaned server-side."""
    seed = _seed_study(server.address, "isolate")
    batch = VizierBatchClient(server.address)
    with pytest.raises(BatchSuggestionError) as ei:
        batch.get_suggestions([
            {"study_name": seed.study_name, "client_id": "w"},
            {"study_name": "owners/x/studies/nope", "client_id": "w"},
        ])
    errors = ei.value.errors
    assert errors[0] is None and errors[1] is not None
    results = ei.value.results
    assert results[1] is None
    assert results[0] is not None and len(results[0]) == 1  # usable handle
    assert results[0][0].client_id == "w"
    batch.close()
    seed.close()


def test_batch_malformed_subrequest_isolated(server):
    """Missing required fields error per-item, not per-batch."""
    seed = _seed_study(server.address, "malformed")
    rpc = RpcClient(server.address)
    result = rpc.call("BatchSuggestTrials", {"requests": [
        {"parent": seed.study_name, "suggestion_count": 1, "client_id": "w"},
        {"client_id": "w"},  # no "parent"
    ]})
    assert result["errors"][0] is None
    assert result["errors"][1] is not None
    assert result["operations"][0] is not None

    result = rpc.call("BatchCompleteTrials", {"requests": [
        {"metrics": {}},  # no "name"
    ]})
    assert result["trials"] == [None]
    assert result["errors"][0] is not None
    rpc.close()
    seed.close()


def test_batch_over_tcp_pipelined():
    """call_many pipelines frames over one socket (server round-trips them)."""
    ds = InMemoryDatastore()
    servicer = VizierService(ds, InProcessPythia(ds))
    rpc_server = RpcServer(servicer).start()
    try:
        rpc = RpcClient(rpc_server.address)
        results = rpc.call_many("Ping", [{} for _ in range(16)])
        assert len(results) == 16
        assert all("time" in r for r in results)
        rpc.close()
    finally:
        servicer.shutdown()
        rpc_server.stop()


def test_batch_concurrent_batched_clients(server):
    """Many VizierBatchClients hammering one server stay consistent."""
    names = [_seed_study(server.address, f"conc-{i}").study_name for i in range(2)]
    errs = []

    def worker(wid):
        try:
            batch = VizierBatchClient(server.address)
            for r in range(3):
                results = batch.get_suggestions([
                    {"study_name": n, "client_id": f"c{wid}", "count": 1}
                    for n in names
                ])
                batch.complete_trials([
                    {"trial_name": f"{n}/trials/{trials[0].id}",
                     "metrics": {"obj": 0.1 * wid + 0.01 * r}}
                    for n, trials in zip(names, results)
                ])
            batch.close()
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
