"""Multi-metric GP-bandit: Pareto-aware acquisition on the shared engine.

Covers the schema-v4 per-metric state record (roundtrip, strict decode,
name-order/dim compatibility, v3 cold start), the multi-metric suggestion
path end to end through the service (GP path — not the old silent random
fallback), the engine compile pin (one compiled kernel set regardless of
metric count k), the remote frame budget (1 GetTrialsMulti + 1
PythiaBatchSuggest per coalesced batch, unchanged by multi-metric), the
non-finite-objective regressions (NaN/inf trials never optimal, never in a
GP fit), and the policy-construction error mapping (INVALID_ARGUMENT, not
retryable INTERNAL).
"""

import json
import math

import numpy as np
import pytest

from repro.core import Measurement, StudyConfig, Trial
from repro.core.metadata import MetadataDelta, Namespace
from repro.core.study import Study
from repro.pythia.converters import TrialToArrayConverter, trials_to_xy
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.posterior import TRACE_COUNTS, reset_trace_counts
from repro.pythia.registry import PolicyConstructionError, make_policy
from repro.pythia.state import (
    GP_BANDIT_NAMESPACE,
    STATE_KEY,
    STATE_SCHEMA_VERSION,
    PolicyState,
    StateDecodeError,
    load_metric_states,
)
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service import (
    DefaultVizierServer,
    DistributedVizierServer,
    OperationFailedError,
    VizierBatchClient,
    VizierClient,
)
from repro.service.datastore import InMemoryDatastore

# -- fixtures ----------------------------------------------------------------


def _mm_config(k: int = 2, algorithm: str = "DEFAULT") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0)
    root.add_float_param("y", 0.0, 1.0)
    for j in range(k):
        cfg.metrics.add(f"m{j}", "MAXIMIZE")
    cfg.algorithm = algorithm
    return cfg


_CENTERS = [(0.2, 0.7), (0.8, 0.3), (0.5, 0.95)]


def _objectives(params: dict, k: int) -> dict:
    return {
        f"m{j}": -((params["x"] - cx) ** 2 + (params["y"] - cy) ** 2)
        for j, (cx, cy) in enumerate(_CENTERS[:k])
    }


def _seed_study(client: VizierClient, k: int, n: int = 8) -> None:
    for i in range(n):
        params = {"x": (i + 1) / (n + 1.0), "y": ((i * 3) % 7) / 7.0}
        t = Trial(parameters=params)
        t.complete(Measurement(metrics=_objectives(params, k)))
        client.add_trial(t)


def _stored_state(datastore, study_name: str) -> PolicyState:
    md = datastore.get_study(study_name).study_config.metadata
    blob = md.abs_ns(Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
    assert blob is not None, "no persisted GP-bandit state"
    return PolicyState.from_value(blob)


def _policy_loop_setup(k: int, name: str):
    """Direct datastore + policy, no server: the benchmark-style loop."""
    cfg = _mm_config(k)
    ds = InMemoryDatastore()
    study = Study(name=f"owners/t/studies/{name}", study_config=cfg)
    ds.create_study(study)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = make_policy("DEFAULT", supporter, cfg)
    return ds, study, policy


def _run_op(ds, study, policy, count: int = 1):
    config = ds.get_study(study.name).study_config  # fresh metadata
    return policy.suggest(SuggestRequest(
        study_descriptor=StudyDescriptor(config=config, guid=study.name),
        count=count))


def _complete(ds, study, params: dict, k: int) -> None:
    t = Trial(parameters=dict(params))
    t.complete(Measurement(metrics=_objectives(params, k)))
    ds.create_trial(study.name, t)


def _seed_direct(ds, study, k: int, n: int = 8) -> None:
    for i in range(n):
        _complete(ds, study,
                  {"x": (i + 1) / (n + 1.0), "y": ((i * 3) % 7) / 7.0}, k)


# -- end to end through the service ------------------------------------------


def test_multi_metric_suggestions_end_to_end():
    """A 2-metric DEFAULT study served in-process: batch of 3 distinct
    in-bounds suggestions from the GP path, frontier + hypervolume readable
    through the client API."""
    server = DefaultVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "mm-e2e", _mm_config(k=2), client_id="w", target=server.address)
        _seed_study(c, k=2)
        trials = c.get_suggestions(count=3)
        assert len(trials) == 3
        seen = set()
        for t in trials:
            p = t.parameters.as_dict()
            assert 0.0 <= p["x"] <= 1.0 and 0.0 <= p["y"] <= 1.0
            seen.add((round(p["x"], 9), round(p["y"], 9)))
        assert len(seen) == 3, "batch members collapsed onto one point"
        for t in trials:
            c.complete_trial(_objectives(t.parameters.as_dict(), 2),
                             trial_id=t.id)
        frontier, vectors = c.pareto_frontier()
        assert frontier and len(frontier) == len(vectors)
        assert all(len(v) == 2 and all(math.isfinite(x) for x in v)
                   for v in vectors)
        assert c.hypervolume() > 0.0
        c.close()
    finally:
        server.stop()


def test_multi_metric_uses_gp_path_and_persists_v4():
    """The DEFAULT policy on a multi-metric study fits real GPs (it used to
    silently fall back to random sampling forever): the op persists a
    schema-v4 checkpoint with one named trajectory per metric, in config
    order, metric 0 mirrored at the top level; the second op warm-starts."""
    ds, study, policy = _policy_loop_setup(k=2, name="mm-gp-path")
    _seed_direct(ds, study, k=2)
    decision = _run_op(ds, study, policy)
    assert len(decision.suggestions) == 1
    state = _stored_state(ds, study.name)
    assert state.version == STATE_SCHEMA_VERSION == 4
    assert [ms["name"] for ms in state.metric_states] == ["m0", "m1"]
    assert state.metric_states[0]["raw"] == state.raw  # mirror layout
    assert not state.warm_started
    # per-metric trajectories genuinely differ (k independent fits, one clock)
    assert state.metric_states[0]["raw"] != state.metric_states[1]["raw"]

    p = decision.suggestions[0].parameters
    _complete(ds, study, {"x": p["x"].as_float, "y": p["y"].as_float}, k=2)
    _run_op(ds, study, policy)
    state2 = _stored_state(ds, study.name)
    assert state2.warm_started and state2.num_trials == 9
    assert [ms["name"] for ms in state2.metric_states] == ["m0", "m1"]


def test_single_objective_state_has_empty_metric_states():
    server = DefaultVizierServer()
    try:
        cfg = _mm_config(k=1, algorithm="GP_UCB")
        c = VizierClient.load_or_create_study(
            "mm-single", cfg, client_id="w", target=server.address)
        _seed_study(c, k=1)
        c.get_suggestions(count=1)
        state = _stored_state(server.datastore, c.study_name)
        assert state.metric_states == []
        c.close()
    finally:
        server.stop()


# -- schema v4 record --------------------------------------------------------


def _v4_blob(ds, study, policy) -> dict:
    """A genuine persisted v4 multi-metric blob, as a json object."""
    _seed_direct(ds, study, k=2)
    _run_op(ds, study, policy)
    return json.loads(_stored_state(ds, study.name).to_value())


def test_v4_roundtrip_and_strict_decode():
    ds, study, policy = _policy_loop_setup(k=2, name="mm-blob")
    obj = _v4_blob(ds, study, policy)
    state = PolicyState.from_value(json.dumps(obj))
    assert PolicyState.from_value(state.to_value()) == state
    assert len(state.metric_states) == 2

    # exactly one metric_states entry is invalid on its face: multi-metric
    # records carry k >= 2, single-objective records carry []
    one = dict(obj, metric_states=obj["metric_states"][:1])
    with pytest.raises(StateDecodeError):
        PolicyState.from_value(json.dumps(one))
    # non-list metric_states
    with pytest.raises(StateDecodeError):
        PolicyState.from_value(json.dumps(dict(obj, metric_states={"a": 1})))
    # entry missing its trees
    broken = dict(obj, metric_states=[obj["metric_states"][0],
                                      {"name": "m1"}])
    with pytest.raises(StateDecodeError):
        PolicyState.from_value(json.dumps(broken))


def test_load_metric_states_compatibility_gates():
    """Name-set, name-ORDER, and dim mismatches all cold-start (None), and
    never raise — a stale blob must never fail a suggestion op."""
    ds, study, policy = _policy_loop_setup(k=2, name="mm-compat")
    _v4_blob(ds, study, policy)
    md = ds.get_study(study.name).study_config.metadata
    good = load_metric_states(md, dim=2, num_trials=8,
                              metric_names=["m0", "m1"])
    assert good is not None and len(good.metric_states) == 2
    assert load_metric_states(md, dim=2, num_trials=8,
                              metric_names=["m1", "m0"]) is None  # order
    assert load_metric_states(md, dim=2, num_trials=8,
                              metric_names=["m0", "renamed"]) is None
    assert load_metric_states(md, dim=2, num_trials=8,
                              metric_names=["m0", "m1", "m2"]) is None
    assert load_metric_states(md, dim=5, num_trials=8,
                              metric_names=["m0", "m1"]) is None  # dim skew
    # a single-objective load against the same blob rejects it too
    from repro.pythia.state import load_state
    assert load_state(md, dim=2, num_trials=8) is None


@pytest.mark.parametrize("blob", [
    "garbage",
    json.dumps({"version": 3, "algorithm": "gp_bandit"}),  # pre-multi schema
])
def test_v3_or_corrupt_blob_cold_starts_multi(blob):
    """Schema skew through the live service: plant a v3/corrupt blob, the
    multi-metric suggestion still succeeds, cold-fits, and overwrites the
    blob with a fresh v4 checkpoint."""
    server = DefaultVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            f"mm-skew-{abs(hash(blob)) % 1000}", _mm_config(k=2),
            client_id="w", target=server.address)
        _seed_study(c, k=2)
        delta = MetadataDelta()
        delta.assign(GP_BANDIT_NAMESPACE, STATE_KEY, blob)
        c.update_metadata(delta)

        (t,) = c.get_suggestions(count=1)  # must not error
        assert t.id >= 1
        state = _stored_state(server.datastore, c.study_name)
        assert state.version == STATE_SCHEMA_VERSION
        assert not state.warm_started  # fell back to the cold path
        assert [ms["name"] for ms in state.metric_states] == ["m0", "m1"]
        c.close()
    finally:
        server.stop()


# -- engine compile pin ------------------------------------------------------


def test_one_compiled_kernel_set_across_metric_counts():
    """THE multi-metric engine invariant: ops at k=2 and k=3, at different
    trial counts and batch sizes, all run on at most ONE compiled program
    per engine kernel — per-metric posteriors share bucket shapes, so the
    kernels compiled for metric 0 serve every other metric and k."""
    ds2, study2, policy2 = _policy_loop_setup(k=2, name="mm-compile-k2")
    ds3, study3, policy3 = _policy_loop_setup(k=3, name="mm-compile-k3")
    _seed_direct(ds2, study2, k=2)
    _seed_direct(ds3, study3, k=3, n=11)  # different n, same 64-bucket
    reset_trace_counts()
    d = _run_op(ds2, study2, policy2, count=2)   # batch: rank-1 appends
    p = d.suggestions[0].parameters
    _complete(ds2, study2, {"x": p["x"].as_float, "y": p["y"].as_float}, k=2)
    _run_op(ds2, study2, policy2, count=1)       # n grew within the bucket
    _run_op(ds3, study3, policy3, count=3)       # k=3 study, larger batch
    # <= 1, not == 1: process-wide jit caches may already be warm from other
    # tests — what is pinned is that multi-metric shapes never RETRACE
    assert all(v <= 1 for v in TRACE_COUNTS.values()), dict(TRACE_COUNTS)


def test_pool_mean_std_kernel_ticks_on_fresh_shapes():
    """Sanity for the fused acquisition read the multi path leans on (the
    retrace pin above is not vacuously green): a never-seen bucket traces
    ``pool_mean_std`` exactly once, and the two rows match the separate
    mean/std reads."""
    from repro.pythia.posterior import CholeskyPosterior

    rng = np.random.RandomState(0)
    d = 9  # dimension unused anywhere else in the suite
    raw = {"log_amp": 0.0, "log_ell": np.zeros(d), "log_noise": -2.0}
    reset_trace_counts()
    post = CholeskyPosterior(raw, rng.rand(12, d), rng.randn(12))
    post.set_pool(rng.rand(40, d))
    mean, std = post.pool_mean_std()
    assert TRACE_COUNTS["pool_mean_std"] == 1
    np.testing.assert_allclose(mean, post.pool_mean(), rtol=1e-6)
    np.testing.assert_allclose(std, post.pool_std(), rtol=1e-6)
    post.pool_mean_std()
    assert TRACE_COUNTS["pool_mean_std"] == 1  # second read: no retrace


# -- remote frame budget -----------------------------------------------------


def test_remote_frame_budget_unchanged_by_multimetric():
    """Figure-2 split with k=2: one coalesced batch still costs exactly one
    GetTrialsMulti prefetch and one PythiaBatchSuggest dispatch — the
    per-metric GPs add zero frames (no metadata RPC, no config or trial
    re-fetch)."""
    server = DistributedVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "mm-frames", _mm_config(k=2), client_id="w",
            target=server.address)
        _seed_study(c, k=2)
        batch = VizierBatchClient(server.address)
        (trials,) = batch.get_suggestions(
            [{"study_name": c.study_name, "client_id": "w", "count": 2}])
        assert len(trials) == 2
        for t in trials:
            c.complete_trial(_objectives(t.parameters.as_dict(), 2),
                             trial_id=t.id)

        server.servicer.reset_method_counts()
        server.pythia_servicer.reset_method_counts()
        (trials2,) = batch.get_suggestions(
            [{"study_name": c.study_name, "client_id": "w", "count": 2}])
        assert len(trials2) == 2
        pythia_counts = server.pythia_servicer.method_counts()
        api_counts = server.servicer.method_counts()
        assert pythia_counts.get("PythiaBatchSuggest") == 1
        assert api_counts.get("GetTrialsMulti") == 1
        assert "UpdateMetadata" not in api_counts
        assert "GetStudy" not in api_counts
        assert "ListTrials" not in api_counts
        # and the warm-start state still rode those frames (v4, both metrics)
        state = _stored_state(server.datastore, c.study_name)
        assert state.warm_started
        assert len(state.metric_states) == 2
        batch.close()
        c.close()
    finally:
        server.stop()


# -- non-finite objective regressions ----------------------------------------


def test_nan_trials_never_optimal_live_server():
    """S1 regression through the live service: trials completed with NaN or
    infinite objective values must never appear in ListOptimalTrials — on a
    single-metric study (best-trial selection) or a multi-metric one
    (frontier), and the client frontier/hypervolume helpers skip them."""
    server = DefaultVizierServer()
    try:
        # multi-metric: NaN/inf rows are incomparable, never on the frontier
        c = VizierClient.load_or_create_study(
            "mm-nan", _mm_config(k=2), client_id="w", target=server.address)
        good_ids = []
        for metrics in ({"m0": 1.0, "m1": 1.0}, {"m0": 2.0, "m1": 0.5}):
            (t,) = c.get_suggestions(count=1)
            c.complete_trial(metrics, trial_id=t.id)
            good_ids.append(t.id)
        bad_ids = []
        for metrics in ({"m0": float("nan"), "m1": 5.0},
                        {"m0": float("inf"), "m1": float("inf")},
                        {"m0": 5.0, "m1": float("-inf")}):
            (t,) = c.get_suggestions(count=1)
            c.complete_trial(metrics, trial_id=t.id)
            bad_ids.append(t.id)
        optimal = {t.id for t in c.list_optimal_trials()}
        assert optimal == set(good_ids)
        frontier, vectors = c.pareto_frontier()
        assert {t.id for t in frontier} == set(good_ids)
        assert np.isfinite(np.asarray(vectors)).all()
        assert math.isfinite(c.hypervolume())
        c.close()

        # single-metric: a NaN "maximum" must not shadow the real best
        c1 = VizierClient.load_or_create_study(
            "mm-nan-single", _mm_config(k=1, algorithm="RANDOM_SEARCH"),
            client_id="w", target=server.address)
        (t1,) = c1.get_suggestions(count=1)
        c1.complete_trial({"m0": 0.7}, trial_id=t1.id)
        (t2,) = c1.get_suggestions(count=1)
        c1.complete_trial({"m0": float("nan")}, trial_id=t2.id)
        assert [t.id for t in c1.list_optimal_trials()] == [t1.id]
        c1.close()
    finally:
        server.stop()


def test_nan_trials_never_reach_gp_fit():
    """Poisoned trials are filtered before the design matrix: the fit (and
    the persisted num_trials fingerprint) sees only the finite rows, and
    the suggestion op still succeeds."""
    ds, study, policy = _policy_loop_setup(k=2, name="mm-nan-fit")
    _seed_direct(ds, study, k=2)
    for metrics in ({"m0": float("nan"), "m1": 1.0},
                    {"m0": 1.0, "m1": float("inf")}):
        t = Trial(parameters={"x": 0.5, "y": 0.5})
        t.complete(Measurement(metrics=metrics))
        ds.create_trial(study.name, t)

    # converter level: the xy matrices exclude the two poisoned trials
    cfg = ds.get_study(study.name).study_config
    completed = ds.list_trials(study.name)
    conv = TrialToArrayConverter(cfg.search_space)
    x, y = trials_to_xy(completed, cfg, conv)
    assert x.shape[0] == 8 and np.isfinite(x).all()
    assert y.shape == (8, 2) and np.isfinite(y).all()

    # policy level: op succeeds, checkpoint fingerprints the finite count
    decision = _run_op(ds, study, policy)
    assert len(decision.suggestions) == 1
    assert _stored_state(ds, study.name).num_trials == 8


# -- policy-construction error mapping ---------------------------------------


def test_algorithm_config_mismatch_is_invalid_argument():
    """S3: a single-objective designer explicitly selected on a multi-metric
    study fails the op with INVALID_ARGUMENT (3) — a permanent client error
    the caller should fix, not the retryable INTERNAL (13) it used to be."""
    with pytest.raises(PolicyConstructionError) as ei:
        make_policy("REGULARIZED_EVOLUTION", None, _mm_config(k=2))
    assert ei.value.code == 3
    assert "cannot serve" in str(ei.value)

    server = DefaultVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "mm-mismatch", _mm_config(k=2, algorithm="REGULARIZED_EVOLUTION"),
            client_id="w", target=server.address)
        with pytest.raises(OperationFailedError) as op_err:
            c.get_suggestions(count=1)
        assert op_err.value.code == 3
        ops = server.datastore.list_operations(c.study_name)
        assert ops[0]["done"] and ops[0]["error"]["code"] == 3
        c.close()

        # unknown algorithm: same mapping, message pinned for remote clients
        c2 = VizierClient.load_or_create_study(
            "mm-unknown", _mm_config(k=2, algorithm="GP_UCB"),
            client_id="w", target=server.address)
        study = server.datastore.get_study(c2.study_name)
        study.study_config.algorithm = "NO_SUCH_ALGORITHM"
        server.datastore.update_study(study)
        with pytest.raises(OperationFailedError) as op_err2:
            c2.get_suggestions(count=1)
        assert op_err2.value.code == 3
        assert "unknown algorithm" in str(op_err2.value)
        c2.close()
    finally:
        server.stop()


def test_nsga2_still_serves_multimetric_as_explicit_baseline():
    ds = InMemoryDatastore()
    cfg = _mm_config(k=2, algorithm="NSGA2")
    study = Study(name="owners/t/studies/mm-nsga", study_config=cfg)
    ds.create_study(study)
    policy = make_policy("NSGA2", DatastorePolicySupporter(ds, study.name), cfg)
    decision = _run_op(ds, study, policy, count=2)
    assert len(decision.suggestions) == 2
