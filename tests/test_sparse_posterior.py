"""SGPR inducing-point posterior (repro.pythia.sparse_posterior).

Pins the tentpole's acceptance criteria: with Z = X the sparse posterior is
exact (matches CholeskyPosterior to ~jitter), a chain of rank-1 appends
against the m×m inducing factor equals a fresh factorization with the same
sites, pool rescoring after appends matches a fresh attach, the policy
switches dense -> sparse strictly above SPARSE_THRESHOLD, and every sparse
engine kernel compiles at most once across shape-stable operations.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import Measurement, StudyConfig, Trial
from repro.core.study import Study
from repro.pythia import gp_bandit as gpb
from repro.pythia.gp_bandit import GPBanditPolicy, StackedResidualGP
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.posterior import (
    CholeskyPosterior,
    TRACE_COUNTS,
    reset_trace_counts,
)
from repro.pythia.sparse_posterior import (
    N_INDUCING,
    SPARSE_THRESHOLD,
    SparsePosterior,
    inducing_sites,
)
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service.datastore import InMemoryDatastore


def _raw_tree(d, rng):
    return {
        "log_amp": np.float32(rng.uniform(-0.5, 0.5)),
        "log_ell": np.full((d,), np.log(0.4) + rng.uniform(-0.2, 0.2),
                           np.float32),
        "log_noise": np.float32(rng.uniform(-5.0, -3.0)),
    }


def _design(n, d, seed):
    rng = np.random.RandomState(seed)
    x = rng.rand(n, d).astype(np.float32)
    y = (np.sin(3 * x[:, 0]) + 0.5 * x[:, -1]
         + 0.05 * rng.randn(n)).astype(np.float32)
    return rng, x, y


# ---------------------------------------------------------------------------
# exactness: Z = X makes SGPR the dense posterior (up to jitter)
# ---------------------------------------------------------------------------


def test_sparse_with_z_equal_x_matches_dense():
    rng, x, y = _design(60, 3, 0)
    raw = _raw_tree(3, rng)
    dense = CholeskyPosterior(raw, x, y)
    sparse = SparsePosterior(raw, x, y, z=x)
    xq = rng.rand(40, 3).astype(np.float32)
    md, sd = dense.query(xq)
    ms, ss = sparse.query(xq)
    np.testing.assert_allclose(ms, md, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(ss, sd, atol=1e-3, rtol=1e-3)

    dense.set_pool(xq)
    sparse.set_pool(xq)
    np.testing.assert_allclose(sparse.pool_ucb(1.8), dense.pool_ucb(1.8),
                               atol=2e-3, rtol=2e-3)


def test_mean_is_kernel_matvec_against_inducing_sites():
    """alpha is the inducing-weight vector: K(q, Z) @ alpha must equal the
    posterior mean — the contract the stacked-mean kernels rely on."""
    from repro.kernels import ops as kops

    rng, x, y = _design(200, 3, 1)
    raw = _raw_tree(3, rng)
    post = SparsePosterior(raw, x, y, n_inducing=64, seed=0)
    xq = rng.rand(30, 3).astype(np.float32)
    mean, _ = post.query(xq)
    import jax.numpy as jnp
    ell = np.exp(np.asarray(raw["log_ell"], np.float64))
    amp = float(np.exp(raw["log_amp"]))
    via_matvec = np.asarray(kops.matern52_gram_matvec(
        jnp.asarray(post.inducing_z / ell, jnp.float32),
        jnp.asarray(xq / ell, jnp.float32),
        post.alpha, amp, impl="xla"))
    np.testing.assert_allclose(via_matvec, mean, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rank-1 appends == fresh factorization with the same sites
# ---------------------------------------------------------------------------


@given(st.integers(min_value=30, max_value=80),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=10, deadline=None)
def test_append_equals_refit_property(n, k, seed):
    rng = np.random.RandomState(seed)
    d = 3
    raw = _raw_tree(d, rng)
    x = rng.rand(n, d).astype(np.float32)
    y = rng.randn(n).astype(np.float32)
    adds_x = rng.rand(k, d).astype(np.float32)
    adds_y = rng.randn(k).astype(np.float32)
    z = inducing_sites(32, d, seed=7)

    incremental = SparsePosterior(raw, x, y, z=z, capacity=n + k)
    for ax, ay in zip(adds_x, adds_y):
        incremental.append(ax, ay)
    fresh = SparsePosterior(raw, np.vstack([x, adds_x]),
                            np.concatenate([y, adds_y]), z=z)
    xq = rng.rand(20, d).astype(np.float32)
    m_inc, s_inc = incremental.query(xq)
    m_new, s_new = fresh.query(xq)
    # tolerance scales with 1/noise: the whitened update vector u = Luu^-1
    # k(Z, x*)/sigma grows as sigma shrinks, so f32 accumulation in the
    # cholupdate/Sherman-Morrison chain leaves ~5e-3 worst-case drift at the
    # smallest fitted noise this property draws (~7e-3)
    np.testing.assert_allclose(m_inc, m_new, atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(s_inc, s_new, atol=1e-2, rtol=1e-2)


def test_pool_rescore_after_append_matches_fresh_attach():
    rng, x, y = _design(120, 3, 3)
    raw = _raw_tree(3, rng)
    z = inducing_sites(48, 3, seed=0)
    pool = rng.rand(90, 3).astype(np.float32)

    post = SparsePosterior(raw, x, y, z=z, capacity=x.shape[0] + 2)
    post.set_pool(pool)
    xa = rng.rand(3).astype(np.float32)
    post.append(xa, 0.7)

    fresh = SparsePosterior(raw, np.vstack([x, xa[None]]),
                            np.concatenate([y, [0.7]]), z=z)
    fresh.set_pool(pool)
    np.testing.assert_allclose(post.pool_mean(), fresh.pool_mean(),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(post.pool_std(), fresh.pool_std(),
                               atol=2e-3, rtol=2e-3)


def test_append_pool_member_matches_manual_append_at_cached_mean():
    rng, x, y = _design(100, 3, 4)
    raw = _raw_tree(3, rng)
    pool = rng.rand(70, 3).astype(np.float32)
    a = SparsePosterior(raw, x, y, n_inducing=48, seed=0,
                        capacity=x.shape[0] + 1)
    b = SparsePosterior(raw, x, y, n_inducing=48, seed=0,
                        capacity=x.shape[0] + 1)
    for p in (a, b):
        p.set_pool(pool)
    i = int(np.argmax(a.pool_ucb(1.8)))
    a.append_pool_member(i)
    b.append(pool[i], float(b.pool_mean()[i]))
    np.testing.assert_allclose(a.pool_mean(), b.pool_mean(),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(a.pool_std(), b.pool_std(),
                               atol=1e-4, rtol=1e-4)


def test_append_past_capacity_refuses():
    rng, x, y = _design(30, 2, 5)
    raw = _raw_tree(2, rng)
    post = SparsePosterior(raw, x, y, n_inducing=16, seed=0, capacity=30)
    post.n = post.capacity  # simulate a full design buffer
    with pytest.raises(ValueError, match="capacity"):
        post.append(np.zeros(2, np.float32), 0.0)


# ---------------------------------------------------------------------------
# inducing sites: deterministic per (m, d, seed)
# ---------------------------------------------------------------------------


def test_inducing_sites_deterministic_and_in_unit_cube():
    z1 = inducing_sites(64, 5, seed=3)
    z2 = inducing_sites(64, 5, seed=3)
    np.testing.assert_array_equal(z1, z2)
    assert z1.shape == (64, 5)
    assert (z1 >= 0).all() and (z1 <= 1).all()
    assert not np.array_equal(z1, inducing_sites(64, 5, seed=4))


# ---------------------------------------------------------------------------
# retrace pins: every sparse kernel compiles at most once per shape bucket
# ---------------------------------------------------------------------------


def test_sparse_kernels_do_not_retrace_across_shape_stable_ops():
    rng, x, y = _design(300, 3, 6)
    raw = _raw_tree(3, rng)
    pool = rng.rand(150, 3).astype(np.float32)

    # warm every kernel at the bucket the loop will use
    warm = SparsePosterior(raw, x, y, n_inducing=64, seed=0,
                           capacity=x.shape[0] + 4)
    warm.set_pool(pool)
    warm.append_pool_member(0)
    warm.append(pool[1], 0.1)
    warm.query(pool[:20])

    reset_trace_counts()
    for op in range(3):  # varying n inside one train bucket
        n = 300 + op * 7
        xo = rng.rand(n, 3).astype(np.float32)
        yo = rng.randn(n).astype(np.float32)
        post = SparsePosterior(raw, xo, yo, n_inducing=64, seed=0,
                               capacity=n + 4)
        post.set_pool(pool)
        post.append_pool_member(op)
        post.append(pool[op + 3], 0.2)
        post.query(pool[:20])
    sparse_counts = {k: v for k, v in TRACE_COUNTS.items()
                     if k.startswith("sparse_")}
    # empty == zero retraces (the warm pass populated every jit cache);
    # the tick test below keeps this from being vacuously green
    assert all(v <= 1 for v in sparse_counts.values()), sparse_counts


def test_sparse_trace_counters_tick_on_fresh_shapes():
    """Sanity: the pin above is not vacuously green."""
    rng, x, y = _design(90, 6, 7)  # dimension unused elsewhere in the suite
    raw = _raw_tree(6, rng)
    reset_trace_counts()
    post = SparsePosterior(raw, x, y, n_inducing=16, seed=0)
    post.set_pool(rng.rand(30, 6).astype(np.float32))
    assert TRACE_COUNTS["sparse_factor"] == 1
    assert TRACE_COUNTS["sparse_attach_pool"] == 1


# ---------------------------------------------------------------------------
# policy switch: dense at/below the threshold, sparse strictly above
# ---------------------------------------------------------------------------


def _study_with_trials(n, name):
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("a", 0.0, 1.0)
    root.add_float_param("b", 0.0, 1.0)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name=f"owners/o/studies/{name}", study_config=cfg)
    ds.create_study(study)
    rng = np.random.RandomState(13)
    for _ in range(n):
        a, b = rng.rand(2)
        t = Trial(parameters={"a": a, "b": b})
        t.complete(Measurement(metrics={"y": -(a - 0.3) ** 2 - (b - 0.7) ** 2}))
        ds.create_trial(study.name, t)
    return cfg, ds, study


def _suggest(policy, cfg, study, count=1):
    return policy.suggest(SuggestRequest(
        study_descriptor=StudyDescriptor(config=cfg, guid=study.name),
        count=count)).suggestions


def test_policy_stays_dense_at_or_below_threshold(monkeypatch):
    monkeypatch.setattr(gpb, "SPARSE_THRESHOLD", 40)
    cfg, ds, study = _study_with_trials(40, "dense-at-threshold")
    policy = GPBanditPolicy(DatastorePolicySupporter(ds, study.name),
                            n_candidates=100, min_completed=4,
                            warm_start=False)
    sugg = _suggest(policy, cfg, study)
    assert len(sugg) == 1
    assert policy.last_sparse is False


def test_policy_goes_sparse_above_threshold(monkeypatch):
    monkeypatch.setattr(gpb, "SPARSE_THRESHOLD", 40)
    cfg, ds, study = _study_with_trials(41, "sparse-above-threshold")
    policy = GPBanditPolicy(DatastorePolicySupporter(ds, study.name),
                            n_candidates=100, min_completed=4,
                            warm_start=False)
    sugg = _suggest(policy, cfg, study, count=3)
    assert len(sugg) == 3
    assert policy.last_sparse is True
    for s in sugg:
        p = s.parameters.as_dict()
        assert 0.0 <= p["a"] <= 1.0 and 0.0 <= p["b"] <= 1.0
    # batch members are distinct points (fantasized appends steer away)
    pts = {tuple(sorted(s.parameters.as_dict().items())) for s in sugg}
    assert len(pts) == 3


def test_sparse_level_feeds_stacked_mean_via_inducing_basis(monkeypatch):
    """A sparse level's contribution to the stack mean goes through the
    (Z, alpha_u) basis — finite values, agreeing with the level's query."""
    rng, x, y = _design(SPARSE_THRESHOLD + 50, 3, 8)
    stack = StackedResidualGP(dim=3, seed=0)
    stack.fit_level(x, y, capacity=x.shape[0] + 2)
    lvl = stack.levels[-1]
    assert isinstance(lvl.posterior, SparsePosterior)
    assert lvl.mean_x.shape == (N_INDUCING, 3)
    xq = rng.rand(12, 3).astype(np.float32)
    via_stack = stack.mean(xq)
    via_query, _ = lvl.posterior.query(xq)
    np.testing.assert_allclose(via_stack, via_query, atol=1e-4, rtol=1e-4)
