"""Train loop fault tolerance + serve engine + tuning integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import DecodeEngine, Request
from repro.train.data import DataConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state
from repro.train.train_loop import LoopConfig, train


pytestmark = pytest.mark.slow  # full-model tests; deselect with -m "not slow"


def tiny_arch():
    return dataclasses.replace(
        get_arch("phi4_mini_3p8b", reduced=True),
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
        attn_q_chunk=32, attn_kv_chunk=32, remat="none")


def test_train_descends_and_resumes(tmp_path):
    cfg = tiny_arch()
    model = build_model(cfg)
    tc = TrainConfig(peak_lr=3e-3, warmup_steps=2, total_steps=40)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    ckpt = str(tmp_path / "ckpt")

    r1 = train(model, tc, dc, LoopConfig(total_steps=20, checkpoint_every=10,
                                         checkpoint_dir=ckpt, log_every=100))
    assert r1.final_step == 20 and r1.resumed_from is None
    assert r1.losses[-1] < r1.losses[0]

    # crash + restart: resumes from the checkpoint, not step 0
    r2 = train(model, tc, dc, LoopConfig(total_steps=30, checkpoint_every=10,
                                         checkpoint_dir=ckpt, log_every=100))
    assert r2.resumed_from == 20
    assert r2.final_step == 30
    assert len(r2.losses) == 10  # only the new steps


def test_train_early_stop_hook(tmp_path):
    cfg = tiny_arch()
    model = build_model(cfg)
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=1, total_steps=50)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    calls = []

    def report(step, metrics):
        calls.append(step)
        return step >= 7  # tuner says stop

    r = train(model, tc, dc, LoopConfig(total_steps=50, log_every=100),
              report_fn=report)
    assert r.final_step == 7
    assert calls == list(range(1, 8))


def test_microbatching_matches_full_batch():
    cfg = tiny_arch()
    model = build_model(cfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    from repro.train.data import make_dataset

    batch = {k: jnp.asarray(v) for k, v in make_dataset(dc).batch_at(0).items()}
    losses = {}
    for n_mb in (1, 4):
        tc = TrainConfig(peak_lr=1e-3, warmup_steps=1, num_microbatches=n_mb)
        state = init_train_state(model, tc, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(model, tc))
        _, metrics = step(state, batch)
        losses[n_mb] = float(metrics["loss"])
    assert abs(losses[1] - losses[4]) < 0.02, losses


def test_grad_compression_trains():
    cfg = tiny_arch()
    model = build_model(cfg)
    tc = TrainConfig(peak_lr=3e-3, warmup_steps=2, grad_compression=True)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    r = train(model, tc, dc, LoopConfig(total_steps=15, log_every=100))
    assert r.losses[-1] < r.losses[0]


def test_serve_engine_continuous_batching():
    cfg = tiny_arch()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = DecodeEngine(model, params, batch_size=2, max_seq=32)
    for uid in range(5):
        engine.submit(Request(uid=uid, prompt=[1, 2, 3], max_new_tokens=4))
    done = engine.run_until_done()
    assert len(done) == 5
    for req in done:
        assert len(req.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in req.output)


def test_tuning_worker_end_to_end(tmp_path):
    from repro.core import ScaleType, StudyConfig, TrialState
    from repro.service import VizierClient
    from repro.service.datastore import InMemoryDatastore
    from repro.service.vizier_service import VizierService
    from repro.tuning import TuningTask, TuningWorker

    study_cfg = StudyConfig()
    study_cfg.search_space.select_root().add_float_param(
        "peak_lr", 1e-4, 1e-2, scale_type=ScaleType.LOG)
    study_cfg.metrics.add("loss", "MINIMIZE")
    study_cfg.algorithm = "RANDOM_SEARCH"

    svc = VizierService(InMemoryDatastore())
    client = VizierClient.load_or_create_study("tw", study_cfg, client_id="a",
                                               target=svc)
    arch = tiny_arch()
    task = TuningTask(
        arch=arch,
        data=DataConfig(vocab_size=arch.vocab_size, seq_len=16, global_batch=2),
        total_steps=8, report_every=4)
    worker = TuningWorker(svc, client.study_name, "worker_0", task)
    n = worker.run(max_trials=2)
    assert n == 2
    completed = client.list_trials(states=[TrialState.COMPLETED])
    assert len(completed) == 2
    for t in completed:
        assert t.final_objective("loss") is not None
        assert len(t.measurements) >= 1  # learning curve was streamed
    svc.shutdown()
