"""Automated stopping rules (paper Appendix B.1)."""

from repro.core import (
    AutomatedStoppingConfig,
    Measurement,
    StudyConfig,
    Trial,
)
from repro.core.early_stopping import should_stop


def curve_trial(uid, values, final=None) -> Trial:
    t = Trial(id=uid)
    for i, v in enumerate(values):
        t.add_measurement(Measurement(metrics={"acc": v}, steps=(i + 1) * 10))
    if final is not None:
        t.complete(Measurement(metrics={"acc": final}))
    return t


def config_with(stopping) -> StudyConfig:
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1)
    cfg.metrics.add("acc", "MAXIMIZE")
    cfg.automated_stopping = stopping
    return cfg


def test_median_rule_stops_bad_trial():
    cfg = config_with(
        AutomatedStoppingConfig.median_automated_stopping_config(min_completed_trials=2))
    completed = [curve_trial(i, [0.5 + 0.05 * j for j in range(6)], final=0.8)
                 for i in range(1, 4)]
    bad = curve_trial(10, [0.1, 0.12, 0.13])
    good = curve_trial(11, [0.55, 0.65, 0.75])
    assert should_stop(bad, completed + [bad], cfg) is True
    assert should_stop(good, completed + [good], cfg) is False


def test_median_rule_needs_min_completed():
    cfg = config_with(
        AutomatedStoppingConfig.median_automated_stopping_config(min_completed_trials=5))
    completed = [curve_trial(i, [0.5, 0.6], final=0.7) for i in range(1, 3)]
    bad = curve_trial(10, [0.01])
    assert should_stop(bad, completed + [bad], cfg) is False


def test_decay_curve_stops_plateaued_trial():
    cfg = config_with(
        AutomatedStoppingConfig.decay_curve_stopping_config(probability_threshold=0.2))
    completed = [curve_trial(i, [0.4, 0.6, 0.7, 0.75, 0.78, 0.79], final=0.8)
                 for i in range(1, 4)]
    plateaued = curve_trial(10, [0.1, 0.12, 0.125, 0.125, 0.125, 0.125])
    rising = curve_trial(11, [0.3, 0.55, 0.7, 0.78, 0.83, 0.86])
    assert should_stop(plateaued, completed + [plateaued], cfg) is True
    assert should_stop(rising, completed + [rising], cfg) is False


def test_stopping_disabled_and_multiobjective_noop():
    cfg = config_with(AutomatedStoppingConfig())
    bad = curve_trial(1, [0.0])
    assert should_stop(bad, [bad], cfg) is False
    cfg2 = config_with(
        AutomatedStoppingConfig.median_automated_stopping_config())
    cfg2.metrics.add("second", "MINIMIZE")
    assert should_stop(bad, [bad], cfg2) is False


def _stopping_config() -> StudyConfig:
    cfg = StudyConfig()
    cfg.search_space.select_root().add_float_param("x", 0, 1)
    cfg.metrics.add("acc", "MAXIMIZE")
    cfg.algorithm = "RANDOM_SEARCH"
    cfg.automated_stopping = (
        AutomatedStoppingConfig.median_automated_stopping_config(
            min_completed_trials=1))
    return cfg


def test_early_stopping_remote_pythia():
    """The PythiaEarlyStop path over the Figure-2 split: the stop decision
    must match what the in-process policy decides on the same state."""
    import pytest
    from repro.service import DistributedVizierServer, VizierClient
    from repro.service.rpc import RpcClient, StatusCode, VizierRpcError

    server = DistributedVizierServer()
    try:
        client = VizierClient.load_or_create_study(
            "es-remote", _stopping_config(), client_id="c",
            target=server.address)
        (t,) = client.get_suggestions(count=1)
        for step, v in [(10, 0.5), (20, 0.7), (30, 0.9)]:
            client.report_intermediate_objective_value(
                {"acc": v}, trial_id=t.id, step=step)
        client.complete_trial({"acc": 0.9}, trial_id=t.id)
        (bad,) = client.get_suggestions(count=1)
        client.report_intermediate_objective_value(
            {"acc": 0.05}, trial_id=bad.id, step=10)
        client.report_intermediate_objective_value(
            {"acc": 0.06}, trial_id=bad.id, step=20)
        # the early-stop op travels API server -> Pythia service -> back
        server.pythia_servicer.reset_method_counts()
        assert client.should_trial_stop(bad.id) is True
        assert server.pythia_servicer.method_counts().get("PythiaEarlyStop") == 1
        # the STOPPING state landed in the datastore
        assert server.datastore.get_trial(
            client.study_name, bad.id).state.value == "STOPPING"

        rpc = RpcClient(server.pythia_address)
        # empty trial_ids: a valid no-op, not an error
        result = rpc.call("PythiaEarlyStop",
                          {"study_name": client.study_name, "trial_ids": []})
        assert result["decisions"] == []
        # unknown study: NOT_FOUND surfaces with its code intact
        with pytest.raises(VizierRpcError) as ei:
            rpc.call("PythiaEarlyStop",
                     {"study_name": "owners/x/studies/nope", "trial_ids": [1]})
        assert ei.value.code == StatusCode.NOT_FOUND
        rpc.close()
        client.close()
    finally:
        server.stop()


def test_early_stopping_through_service(basic_config):
    from repro.core import AutomatedStoppingType
    from repro.service import VizierClient
    from repro.service.datastore import InMemoryDatastore
    from repro.service.vizier_service import VizierService

    basic_config.automated_stopping = (
        AutomatedStoppingConfig.median_automated_stopping_config(
            min_completed_trials=1))
    svc = VizierService(InMemoryDatastore())
    client = VizierClient.load_or_create_study("es", basic_config,
                                               client_id="c", target=svc)
    # one good completed trial
    (t,) = client.get_suggestions(count=1)
    for step, v in [(10, 0.5), (20, 0.7), (30, 0.9)]:
        client.report_intermediate_objective_value({"acc": v}, trial_id=t.id,
                                                   step=step)
    client.complete_trial({"acc": 0.9}, trial_id=t.id)
    # a clearly-worse pending trial should be told to stop
    (bad,) = client.get_suggestions(count=1)
    client.report_intermediate_objective_value({"acc": 0.05}, trial_id=bad.id, step=10)
    client.report_intermediate_objective_value({"acc": 0.06}, trial_id=bad.id, step=20)
    assert client.should_trial_stop(bad.id) is True
    svc.shutdown()
