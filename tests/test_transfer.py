"""Transfer learning across prior studies: the stacked residual GP.

Covers cross-space trial alignment (missing/extra/infeasible parameters
through the CURRENT study's featurizer), the featurizer's imputation policy
(one bad stored value never crashes a suggest), the StackedResidualGP itself,
the policy end to end (prior head start, graceful degradation on deleted
priors, state schema v2 prior fingerprints), and the Figure-2 split (priors
ride the single GetTrialsMulti frame — frame counts pinned).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.core.metadata import Namespace
from repro.core.study import Study
from repro.pythia.converters import TrialToArrayConverter, align_prior_trials
from repro.pythia.gp_bandit import GPBanditPolicy, StackedResidualGP, _zscore
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.state import GP_BANDIT_NAMESPACE, STATE_KEY, PolicyState
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service import (
    DefaultVizierServer,
    DistributedVizierServer,
    VizierBatchClient,
    VizierClient,
)
from repro.service.datastore import InMemoryDatastore


def _gp_config(algorithm: str = "GP_UCB") -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = algorithm
    return cfg


def _mixed_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("lr", 1e-4, 1e-1, scale_type=ScaleType.LOG)
    root.add_categorical_param("act", ["relu", "gelu"])
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def _completed(params: dict, value: float, uid: int = 0) -> Trial:
    t = Trial(id=uid, parameters=params)
    t.complete(Measurement(metrics={"obj": value}))
    return t


def _prior_objective(x: float, y: float) -> float:
    return -((x - 0.30) ** 2) - 0.5 * ((y - 0.60) ** 2)


def _seed_prior_trials(n: int = 30, seed: int = 0):
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        x, y = float(rng.rand()), float(rng.rand())
        out.append(_completed({"x": x, "y": y}, _prior_objective(x, y), i + 1))
    return out


# ---------------------------------------------------------------------------
# Featurizer hardening: the imputation policy
# ---------------------------------------------------------------------------


def test_out_of_domain_categorical_imputes_instead_of_crashing():
    cfg = _mixed_config()
    conv = TrialToArrayConverter(cfg.search_space)
    good = Trial(parameters={"lr": 1e-2, "act": "relu"})
    stale = Trial(parameters={"lr": 1e-2, "act": "swish"})  # not in domain
    feats = conv.to_features([good.parameters, stale.parameters])
    # out-of-domain category featurizes like a missing value: uniform mass
    onehot_stale = feats[1, 1:3]
    np.testing.assert_allclose(onehot_stale, [0.5, 0.5])
    onehot_good = feats[0, 1:3]
    np.testing.assert_allclose(onehot_good, [1.0, 0.0])


def test_unparsable_numeric_imputes_midpoint():
    cfg = _gp_config()
    conv = TrialToArrayConverter(cfg.search_space)
    garbage = Trial(parameters={"x": "not-a-number", "y": 0.25})
    feats = conv.to_features([garbage.parameters])
    assert feats[0, 0] == 0.5  # imputed
    assert feats[0, 1] == 0.25


def test_conditional_indicator_zero_for_infeasible_value():
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    model = root.add_categorical_param("model", ["linear", "dnn"])
    model.select_values(["dnn"]).add_int_param("layers", 1, 5)
    conv = TrialToArrayConverter(cfg.search_space)
    ok = {"model": "dnn", "layers": 3}
    bad = {"model": "dnn", "layers": "three"}
    feats = conv.to_features([
        Trial(parameters=ok).parameters, Trial(parameters=bad).parameters])
    # layout: model one-hot (2) + layers value + layers active indicator
    assert feats[0, 3] == 1.0  # feasible child: active
    assert feats[1, 2] == 0.5 and feats[1, 3] == 0.0  # imputed: inactive


# ---------------------------------------------------------------------------
# Cross-space alignment
# ---------------------------------------------------------------------------


def test_align_prior_trials_missing_extra_infeasible():
    current = _mixed_config()
    conv = TrialToArrayConverter(current.search_space)
    prior_cfg = _mixed_config()  # same metric, overlapping space
    trials = [
        _completed({"lr": 1e-3, "act": "relu"}, 1.0, 1),           # aligned
        _completed({"lr": 1e-2}, 0.5, 2),                          # missing act
        _completed({"lr": 1e-2, "act": "gelu", "wd": 0.1}, 0.2, 3),  # extra wd
        _completed({"lr": 1e-2, "act": "swish"}, 0.1, 4),          # infeasible
        _completed({"wd": 0.3}, 0.0, 5),                           # no overlap
        Trial(id=6, parameters={"lr": 1e-3, "act": "relu"}),       # incomplete
    ]
    x, y = align_prior_trials(trials, prior_cfg, conv)
    # no-overlap and incomplete trials dropped; the rest align (imputed)
    assert x.shape == (4, conv.dim)
    np.testing.assert_allclose(y, [1.0, 0.5, 0.2, 0.1])


def test_align_prior_trials_uses_prior_studys_goal():
    current = _gp_config()
    conv = TrialToArrayConverter(current.search_space)
    prior_cfg = StudyConfig()
    prior_cfg.search_space.select_root().add_float_param("x", 0.0, 1.0)
    prior_cfg.metrics.add("loss", "MINIMIZE")  # different name AND goal
    trials = []
    for uid, (xv, loss) in enumerate([(0.2, 2.0), (0.8, 1.0)], start=1):
        t = Trial(id=uid, parameters={"x": xv})
        t.complete(Measurement(metrics={"loss": loss}))
        trials.append(t)
    _x, y = align_prior_trials(trials, prior_cfg, conv)
    # MINIMIZE flips sign: smaller loss is the larger label
    np.testing.assert_allclose(y, [-2.0, -1.0])
    assert np.argmax(y) == 1


@given(st.lists(st.tuples(
    st.booleans(),    # include x?
    st.booleans(),    # include y?
    st.booleans(),    # add an extra unknown parameter?
    st.sampled_from([0.25, 0.75, "garbage", -3.5]),  # x value (maybe bad)
    st.floats(min_value=-10, max_value=10, allow_nan=False,
              allow_infinity=False),
), min_size=1, max_size=12))
@settings(max_examples=30, deadline=None)
def test_alignment_never_raises_property(specs):
    """Arbitrary combinations of missing/extra/infeasible prior parameters
    featurize without error and with the right shapes."""
    current = _gp_config()
    conv = TrialToArrayConverter(current.search_space)
    prior_cfg = _gp_config()
    trials = []
    for i, (has_x, has_y, extra, xv, obj) in enumerate(specs):
        params = {}
        if has_x:
            params["x"] = xv
        if has_y:
            params["y"] = 0.5
        if extra:
            params["z_unknown"] = "whatever"
        trials.append(_completed(params, obj, i + 1))
    x, y = align_prior_trials(trials, prior_cfg, conv)
    assert x.shape[1] == conv.dim
    assert x.shape[0] == y.shape[0] <= len(specs)
    assert np.isfinite(x).all() and (x >= 0).all() and (x <= 1).all()


# ---------------------------------------------------------------------------
# StackedResidualGP
# ---------------------------------------------------------------------------


def test_stack_mean_is_sum_of_levels_and_std_is_top():
    rng = np.random.RandomState(3)
    x1 = rng.rand(40, 2)
    y1 = -((x1[:, 0] - 0.3) ** 2) - (x1[:, 1] - 0.6) ** 2
    x2 = rng.rand(25, 2)
    y2 = -((x2[:, 0] - 0.35) ** 2) - (x2[:, 1] - 0.55) ** 2

    stack = StackedResidualGP(dim=2)
    stack.fit_level(x1, _zscore(y1))
    stack.fit_level(x2, _zscore(y2))
    assert stack.depth == 2

    xq = rng.rand(10, 2)
    mean, std = stack.predict(xq)
    np.testing.assert_allclose(mean, stack.mean(xq), rtol=1e-5, atol=1e-5)
    # top-level variance only: re-derive from the top level directly
    from repro.pythia.gp_bandit import _posterior
    import jax.numpy as jnp

    top = stack.levels[-1]
    _m, s_top = _posterior(top.raw, top.x, top.y, jnp.asarray(xq, jnp.float32))
    # predict() serves std from the bucket-padded cached factorization; the
    # padding is exact in math but reorders f32 ops vs the unpadded oracle
    np.testing.assert_allclose(std, np.asarray(s_top), rtol=1e-5, atol=1e-6)
    assert std.shape == (10,)


def test_stack_improves_fit_on_shifted_objective():
    """A residual level on sparse shifted data + a dense prior predicts the
    shifted objective better than a single GP on the sparse data alone."""
    rng = np.random.RandomState(7)
    xp = rng.rand(60, 2)
    yp = np.array([_prior_objective(a, b) for a, b in xp])
    shifted = lambda a, b: -((a - 0.37) ** 2) - 0.5 * ((b - 0.53) ** 2)
    xc = rng.rand(6, 2)
    yc = np.array([shifted(a, b) for a, b in xc])

    stacked = StackedResidualGP(dim=2)
    stacked.fit_level(xp, _zscore(yp))
    stacked.fit_level(xc, _zscore(yc))

    solo = StackedResidualGP(dim=2)
    solo.fit_level(xc, _zscore(yc))

    xq = rng.rand(200, 2)
    truth = _zscore(np.array([shifted(a, b) for a, b in xq]))
    # compare argmax location quality: the stacked model should rank the true
    # optimum region higher than the 6-point solo model
    err_stacked = np.corrcoef(stacked.predict(xq)[0], truth)[0, 1]
    err_solo = np.corrcoef(solo.predict(xq)[0], truth)[0, 1]
    assert err_stacked > err_solo


# ---------------------------------------------------------------------------
# Policy end to end (in process)
# ---------------------------------------------------------------------------


def _make_ds_with_prior(n_prior: int = 30, n_current: int = 0):
    ds = InMemoryDatastore()
    prior = Study(name="owners/t/studies/prior", study_config=_gp_config())
    ds.create_study(prior)
    for t in _seed_prior_trials(n_prior):
        ds.create_trial(prior.name, t)
    cfg = _gp_config()
    cfg.prior_study_names = [prior.name]
    current = Study(name="owners/t/studies/current", study_config=cfg)
    ds.create_study(current)
    rng = np.random.RandomState(42)
    for i in range(n_current):
        x, y = float(rng.rand()), float(rng.rand())
        ds.create_trial(current.name, _completed(
            {"x": x, "y": y}, _prior_objective(x, y)))
    return ds, current


def _suggest_once(ds, study, count: int = 1):
    config = ds.get_study(study.name).study_config  # fresh metadata snapshot
    policy = GPBanditPolicy(DatastorePolicySupporter(ds, study.name))
    decision = policy.suggest(SuggestRequest(
        study_descriptor=StudyDescriptor(config=config, guid=study.name),
        count=count))
    return decision, policy


def test_policy_uses_prior_stack_before_any_current_trials():
    """With zero completed current trials a prior-backed study suggests from
    the stack (not random) and lands near the prior optimum — the transfer
    head start."""
    ds, current = _make_ds_with_prior(n_prior=30, n_current=0)
    decision, policy = _suggest_once(ds, current)
    assert policy.last_transfer_levels == 1
    (s,) = decision.suggestions
    p = s.parameters.as_dict()
    # the suggested point should score well on the prior landscape
    assert _prior_objective(p["x"], p["y"]) > -0.08


def test_policy_prior_plus_current_fits_and_stores_v2_state():
    ds, current = _make_ds_with_prior(n_prior=30, n_current=8)
    decision, policy = _suggest_once(ds, current)
    assert len(decision.suggestions) == 1
    assert policy.last_transfer_levels == 1
    blob = ds.get_study(current.name).study_config.metadata.abs_ns(
        Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
    state = PolicyState.from_value(blob)
    assert state.prior_fingerprints == {"owners/t/studies/prior": 30}


def test_policy_missing_prior_degrades_to_cold_single_study_fit():
    ds = InMemoryDatastore()
    cfg = _gp_config()
    cfg.prior_study_names = ["owners/t/studies/deleted-long-ago"]
    current = Study(name="owners/t/studies/cur2", study_config=cfg)
    ds.create_study(current)
    rng = np.random.RandomState(1)
    for _ in range(8):
        x, y = float(rng.rand()), float(rng.rand())
        ds.create_trial(current.name, _completed(
            {"x": x, "y": y}, _prior_objective(x, y)))
    decision, policy = _suggest_once(ds, current)
    assert len(decision.suggestions) == 1
    assert policy.last_transfer_levels == 0  # skipped, no error


def test_prior_growth_invalidates_warm_start_fingerprint():
    """Schema v2: a prior study gaining trials changes the residual targets
    the persisted top-level trajectory was fit on -> next fit is cold; the
    fingerprint then re-stabilizes and warm starts resume."""
    ds, current = _make_ds_with_prior(n_prior=30, n_current=8)
    _suggest_once(ds, current)                      # cold, persists v2 state
    _d, policy = _suggest_once(ds, current)
    assert policy.last_fit_warm                     # same priors: warm resume

    ds.create_trial("owners/t/studies/prior",
                    _completed({"x": 0.5, "y": 0.5}, -0.05))  # prior grows
    _d, policy = _suggest_once(ds, current)
    assert policy.last_transfer_levels == 1
    assert not policy.last_fit_warm                 # fingerprint skew: cold
    _d, policy = _suggest_once(ds, current)
    assert policy.last_fit_warm                     # stable again: warm


def test_prior_level_hyperparams_reused_across_operations():
    """Schema v3: the second operation resumes the prior level's persisted
    hyperparameters (no per-prior Adam refit); a grown prior invalidates the
    reuse, and the fingerprint re-stabilizes on the next operation."""
    ds, current = _make_ds_with_prior(n_prior=30, n_current=8)
    _d, p1 = _suggest_once(ds, current)
    assert p1.last_prior_levels_reused == 0      # first op fits the prior
    blob = ds.get_study(current.name).study_config.metadata.abs_ns(
        Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
    state = PolicyState.from_value(blob)
    assert [(l["name"], l["num_trials"]) for l in state.prior_levels] == \
        [("owners/t/studies/prior", 30)]

    _d, p2 = _suggest_once(ds, current)
    assert p2.last_prior_levels_reused == 1      # refit skipped

    ds.create_trial("owners/t/studies/prior",
                    _completed({"x": 0.5, "y": 0.5}, -0.05))  # prior grows
    _d, p3 = _suggest_once(ds, current)
    assert p3.last_prior_levels_reused == 0      # stale level: refit
    _d, p4 = _suggest_once(ds, current)
    assert p4.last_prior_levels_reused == 1      # stable again


def test_prior_level_reuse_survives_current_study_growth():
    """Prior levels reuse prefix-wise even when the TOP-level trajectory is
    invalidated (current study gained trials): only the current study's GP
    refits cold, the prior stack resumes from its checkpoint."""
    ds, current = _make_ds_with_prior(n_prior=30, n_current=8)
    _suggest_once(ds, current)
    ds.create_trial(current.name, _completed({"x": 0.2, "y": 0.8}, -0.1))
    _d, policy = _suggest_once(ds, current)
    assert policy.last_prior_levels_reused == 1
    assert policy.last_fit_warm  # top warm-starts on num_trials growth too


def test_priors_only_suggest_resets_fit_observability():
    """A priors-only suggest (no current trials -> no current-study fit) must
    not report the previous operation's fit stats on a reused instance."""
    ds, current = _make_ds_with_prior(n_prior=30, n_current=8)
    cfg_b = _gp_config()
    cfg_b.prior_study_names = ["owners/t/studies/prior"]
    empty = Study(name="owners/t/studies/empty", study_config=cfg_b)
    ds.create_study(empty)
    policy = GPBanditPolicy(DatastorePolicySupporter(ds, current.name))
    policy.suggest(SuggestRequest(study_descriptor=StudyDescriptor(
        config=ds.get_study(current.name).study_config, guid=current.name),
        count=1))
    assert policy.last_fit_steps > 0
    policy.suggest(SuggestRequest(study_descriptor=StudyDescriptor(
        config=ds.get_study(empty.name).study_config, guid=empty.name),
        count=1))
    assert policy.last_transfer_levels == 1
    assert policy.last_fit_steps == 0
    assert policy.last_fit_seconds == 0.0
    assert not policy.last_fit_warm


def test_self_reference_prior_is_ignored():
    ds, current = _make_ds_with_prior(n_prior=30, n_current=8)
    cfg = ds.get_study(current.name).study_config
    cfg.prior_study_names = [current.name] + cfg.prior_study_names
    ds.update_study(ds.get_study(current.name))
    decision, policy = _suggest_once(ds, current)
    assert len(decision.suggestions) == 1
    assert policy.last_transfer_levels == 1  # only the real prior counts


# ---------------------------------------------------------------------------
# Figure-2 split: priors ride the single prefetch frame
# ---------------------------------------------------------------------------


def _seed_via_client(client: VizierClient, n: int, objective=_prior_objective,
                     seed: int = 0) -> None:
    rng = np.random.RandomState(seed)
    for _ in range(n):
        x, y = float(rng.rand()), float(rng.rand())
        t = Trial(parameters={"x": x, "y": y})
        t.complete(Measurement(metrics={"obj": objective(x, y)}))
        client.add_trial(t)


def _stored_state(datastore, study_name: str) -> PolicyState:
    md = datastore.get_study(study_name).study_config.metadata
    blob = md.abs_ns(Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
    assert blob is not None, "no persisted GP-bandit state"
    return PolicyState.from_value(blob)


def test_remote_transfer_stays_single_frame():
    """Transfer suggest in the Figure-2 split: the prior study's config +
    trials ride the ONE GetTrialsMulti(include_studies, include_priors)
    frame — still exactly 1 PythiaBatchSuggest and 0 GetStudy/ListTrials."""
    server = DistributedVizierServer()
    try:
        prior = VizierClient.load_or_create_study(
            "xfer-prior", _gp_config(), client_id="seed",
            target=server.address)
        _seed_via_client(prior, 12)
        c = VizierClient.load_or_create_study(
            "xfer-target", _gp_config(), client_id="w",
            target=server.address, prior_studies=[prior.study_name])
        _seed_via_client(c, 8, seed=5)

        server.servicer.reset_method_counts()
        server.pythia_servicer.reset_method_counts()
        batch = VizierBatchClient(server.address)
        (trials,) = batch.get_suggestions(
            [{"study_name": c.study_name, "client_id": "w", "count": 1}])
        assert len(trials) == 1

        pythia_counts = server.pythia_servicer.method_counts()
        api_counts = server.servicer.method_counts()
        assert pythia_counts.get("PythiaBatchSuggest") == 1
        assert api_counts.get("GetTrialsMulti") == 1
        assert "GetStudy" not in api_counts
        assert "ListTrials" not in api_counts
        assert "UpdateMetadata" not in api_counts
        # the stacked fit really happened: v2 state fingerprints the prior
        state = _stored_state(server.datastore, c.study_name)
        assert state.prior_fingerprints == {prior.study_name: 12}
        batch.close()
        prior.close()
        c.close()
    finally:
        server.stop()


def test_remote_transfer_deleted_prior_degrades_not_fails():
    server = DistributedVizierServer()
    try:
        prior = VizierClient.load_or_create_study(
            "doomed-prior", _gp_config(), client_id="seed",
            target=server.address)
        _seed_via_client(prior, 12)
        c = VizierClient.load_or_create_study(
            "survivor", _gp_config(), client_id="w",
            target=server.address, prior_studies=[prior.study_name])
        _seed_via_client(c, 8, seed=5)
        prior.delete_study()  # the prior vanishes before the first suggest

        (t,) = c.get_suggestions(count=1)  # must not error
        assert t.id >= 1
        state = _stored_state(server.datastore, c.study_name)
        assert state.prior_fingerprints == {}  # cold single-study fit
        prior.close()
        c.close()
    finally:
        server.stop()


def test_in_process_transfer_topology():
    """Same transfer path through DefaultVizierServer (in-process Pythia)."""
    server = DefaultVizierServer()
    try:
        prior = VizierClient.load_or_create_study(
            "ip-prior", _gp_config(), client_id="seed", target=server.address)
        _seed_via_client(prior, 12)
        c = VizierClient.load_or_create_study(
            "ip-target", _gp_config(), client_id="w", target=server.address,
            prior_studies=[prior.study_name])
        (t,) = c.get_suggestions(count=1)  # zero current trials: pure stack
        assert t.id >= 1
        p = t.parameters.as_dict()
        assert _prior_objective(p["x"], p["y"]) > -0.15
        prior.close()
        c.close()
    finally:
        server.stop()
