"""Persistent algorithm state: the warm-started GP-bandit (paper §6.3).

Covers the PolicyState record itself (strict decode, version/dim/fingerprint
validation), the warm-started GP fit (resume + convergence exit, cold path
pinned unchanged), cold-vs-warm suggestion equivalence through the service,
the state roundtrip through both topologies with frame counts asserted, the
corruption/version-skew fallback, and property-based metadata namespace
roundtrips via the hypothesis shim.
"""

import itertools
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import Measurement, ScaleType, StudyConfig, Trial
from repro.core.metadata import Metadata, MetadataDelta, Namespace
from repro.pythia.gp_bandit import GaussianProcessBandit, GPBanditPolicy
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.state import (
    GP_BANDIT_NAMESPACE,
    STATE_KEY,
    STATE_SCHEMA_VERSION,
    PolicyState,
    StateDecodeError,
    load_state,
    store_state,
)
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service import (
    DefaultVizierServer,
    DistributedVizierServer,
    VizierBatchClient,
    VizierClient,
)
from repro.service.datastore import InMemoryDatastore


def _gp_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("x", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("y", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("obj", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def _objective(params: dict) -> float:
    return -((params["x"] - 0.37) ** 2) - 0.5 * (params["y"] - 0.61) ** 2


def _seed_study(client: VizierClient, n: int = 8) -> None:
    for i in range(n):
        x = (i + 1) / (n + 1.0)
        y = ((i * 3) % 7) / 7.0
        t = Trial(parameters={"x": x, "y": y})
        t.complete(Measurement(metrics={"obj": _objective({"x": x, "y": y})}))
        client.add_trial(t)


def _stored_state(datastore, study_name: str) -> PolicyState:
    md = datastore.get_study(study_name).study_config.metadata
    blob = md.abs_ns(Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY)
    assert blob is not None, "no persisted GP-bandit state"
    return PolicyState.from_value(blob)


def _wipe_state(datastore, study_name: str) -> None:
    study = datastore.get_study(study_name)
    study.study_config.metadata.clear_ns(GP_BANDIT_NAMESPACE)
    datastore.update_study(study)


def _fit_data(n: int = 50, d: int = 3, seed: int = 0):
    rng = np.random.RandomState(seed)
    x = rng.rand(n, d)
    y = (np.sin(3 * x[:, 0]) + 0.5 * np.cos(2 * x[:, 1])
         - (x[:, 2] - 0.4) ** 2 + 0.05 * rng.randn(n))
    return x, (y - y.mean()) / (y.std() + 1e-9)


def _example_state(dim: int = 3, num_trials: int = 12, **overrides) -> PolicyState:
    vec = [0.1 * (i + 1) for i in range(dim)]
    fields = dict(
        dim=dim, num_trials=num_trials,
        raw={"log_amp": 0.25, "log_ell": vec, "log_noise": -4.0},
        adam_m={"log_amp": 0.0, "log_ell": [0.0] * dim, "log_noise": 0.01},
        adam_v={"log_amp": 0.5, "log_ell": [0.2] * dim, "log_noise": 0.3},
        adam_t=60, steps_run=60, warm_started=False, converged=True,
    )
    fields.update(overrides)
    return PolicyState(**fields)


# ---------------------------------------------------------------------------
# PolicyState record: strict decode + validation
# ---------------------------------------------------------------------------


def test_state_json_roundtrip():
    state = _example_state()
    back = PolicyState.from_value(state.to_value())
    assert back == state
    # bytes blobs (metadata values may be bytes on the wire) decode too
    assert PolicyState.from_value(state.to_value().encode()) == state


@pytest.mark.parametrize("blob", [
    None,
    b"\xff\xfe not utf-8 \x80",
    "not json at all",
    "[1, 2, 3]",
    '{"version": 1}',  # missing everything else
    json.dumps({"version": 999, "algorithm": "gp_bandit", "dim": 3}),
    # non-finite hyperparameters
    _example_state().to_value().replace("0.25", "NaN"),
    # wrong log_ell length vs dim
    json.dumps({**json.loads(_example_state().to_value()), "dim": 5}),
])
def test_state_decode_rejects_bad_blobs(blob):
    with pytest.raises(StateDecodeError):
        PolicyState.from_value(blob)


def test_state_v3_prior_levels_roundtrip():
    tree = {"log_amp": 0.1, "log_ell": [0.2, 0.3, 0.4], "log_noise": -4.0}
    state = _example_state(prior_levels=[
        {"name": "owners/o/studies/a", "num_trials": 10, "raw": tree}])
    back = PolicyState.from_value(state.to_value())
    assert back.prior_levels == [
        {"name": "owners/o/studies/a", "num_trials": 10, "raw": tree}]


@pytest.mark.parametrize("levels", [
    "not-a-list",
    ["not-a-dict"],
    [{"name": 7, "num_trials": 3,
      "raw": {"log_amp": 0.0, "log_ell": [0.0] * 3, "log_noise": 0.0}}],
    [{"name": "a", "num_trials": -1,
      "raw": {"log_amp": 0.0, "log_ell": [0.0] * 3, "log_noise": 0.0}}],
    [{"name": "a", "num_trials": 3, "raw": {"log_amp": 0.0}}],
    [{"name": "a", "num_trials": 3,
      "raw": {"log_amp": 0.0, "log_ell": [0.0] * 99, "log_noise": 0.0}}],
])
def test_state_decode_rejects_bad_prior_levels(levels):
    obj = json.loads(_example_state().to_value())
    obj["prior_levels"] = levels
    with pytest.raises(StateDecodeError):
        PolicyState.from_value(json.dumps(obj))


def test_load_prior_levels_prefix_semantics():
    """Reuse covers the longest matching (name, count) prefix; a mismatch
    invalidates that level and everything above it, never the prefix below.
    The top-level fingerprint is deliberately ignored."""
    from repro.pythia.state import load_prior_levels

    tree_a = {"log_amp": 0.1, "log_ell": [0.1] * 3, "log_noise": -4.0}
    tree_b = {"log_amp": 0.2, "log_ell": [0.2] * 3, "log_noise": -5.0}
    state = _example_state(num_trials=999, prior_levels=[
        {"name": "A", "num_trials": 10, "raw": tree_a},
        {"name": "B", "num_trials": 20, "raw": tree_b},
    ])
    md = Metadata()
    md.abs_ns(Namespace(GP_BANDIT_NAMESPACE))[STATE_KEY] = state.to_value()

    assert load_prior_levels(md, dim=3, priors=[("A", 10), ("B", 20)]) == \
        [tree_a, tree_b]
    # second prior changed: only the first level is reusable
    assert load_prior_levels(md, dim=3, priors=[("A", 10), ("B", 21)]) == \
        [tree_a]
    # first prior changed: nothing is reusable (residuals shifted downstream)
    assert load_prior_levels(md, dim=3, priors=[("A", 9), ("B", 20)]) == []
    # prior list reordered / renamed: prefix breaks at the first mismatch
    assert load_prior_levels(md, dim=3, priors=[("B", 20), ("A", 10)]) == []
    # more priors than stored levels: the stored prefix still helps
    assert load_prior_levels(md, dim=3,
                             priors=[("A", 10), ("B", 20), ("C", 5)]) == \
        [tree_a, tree_b]
    # dimension mismatch and corrupt blobs degrade to "refit everything"
    assert load_prior_levels(md, dim=4, priors=[("A", 10)]) == []
    md2 = Metadata()
    md2.abs_ns(Namespace(GP_BANDIT_NAMESPACE))[STATE_KEY] = "{corrupt"
    assert load_prior_levels(md2, dim=3, priors=[("A", 10)]) == []
    assert load_prior_levels(Metadata(), dim=3, priors=[("A", 10)]) == []


def test_state_compatibility_checks():
    state = _example_state(dim=3, num_trials=12)
    state.check_compatible(dim=3, num_trials=12)
    state.check_compatible(dim=3, num_trials=40)  # more trials now: fine
    with pytest.raises(StateDecodeError):
        state.check_compatible(dim=4, num_trials=12)  # search space changed
    with pytest.raises(StateDecodeError):
        state.check_compatible(dim=3, num_trials=5)  # datastore rewound
    with pytest.raises(StateDecodeError):
        state.check_compatible(dim=3, num_trials=12, algorithm="other")


def test_load_state_never_raises():
    md = Metadata()
    assert load_state(md, dim=3, num_trials=10) is None  # absent
    md.abs_ns(Namespace(GP_BANDIT_NAMESPACE))[STATE_KEY] = b"\x00garbage"
    assert load_state(md, dim=3, num_trials=10) is None  # corrupt
    delta = MetadataDelta()
    store_state(delta, _example_state(dim=3, num_trials=8))
    md2 = Metadata()
    md2.attach(delta.on_study)
    assert load_state(md2, dim=3, num_trials=10) is not None
    assert load_state(md2, dim=4, num_trials=10) is None  # dim skew
    assert load_state(md2, dim=3, num_trials=2) is None   # rewound store


# ---------------------------------------------------------------------------
# Warm-started fit: resume, convergence exit, cold path pinned unchanged
# ---------------------------------------------------------------------------


def test_fit_warm_start_resumes_and_converges():
    x, y = _fit_data()
    gp = GaussianProcessBandit(dim=3)
    gp.fit(x, y)
    info = gp.last_fit
    assert not info.warm and info.steps_run == gp.fit_steps and info.t == 60

    # roundtrip through the serialized record, as the service would
    state = PolicyState.from_value(
        PolicyState.from_fit(info, dim=3, num_trials=len(x)).to_value())
    for _ in range(6):  # resumed fits accumulate until the gradient plateaus
        gp.fit(x, y, init=state.fit_init())
        state = PolicyState.from_fit(gp.last_fit, dim=3, num_trials=len(x))
        if state.converged:
            break
    assert state.converged and state.warm_started
    assert state.adam_t > gp.fit_steps  # genuinely resumed, not restarted

    # once converged, a warm fit costs ONE gradient evaluation
    gp.fit(x, y, init=state.fit_init())
    assert gp.last_fit.steps_run == 1 and gp.last_fit.converged


def test_convergence_exit_cold_path_unchanged():
    """Regression (satellite fix): adding the convergence exit must not
    change what a default cold fit computes — the exit only fires when the
    MLL genuinely plateaus, which a 60-step cold trajectory never does."""
    x, y = _fit_data()
    raw_default = GaussianProcessBandit(dim=3).fit(x, y)
    gp_pinned = GaussianProcessBandit(dim=3, grad_tol=0.0)  # exit disabled
    raw_noexit = gp_pinned.fit(x, y)
    for key in raw_default:
        np.testing.assert_array_equal(np.asarray(raw_default[key]),
                                      np.asarray(raw_noexit[key]))
    gp = GaussianProcessBandit(dim=3)
    gp.fit(x, y)
    assert gp.last_fit.steps_run == gp.fit_steps and not gp.last_fit.converged


def test_warm_fit_divergence_self_heals_to_cold_init():
    """A restored point that diverges before any finite loss must NOT be
    persisted again — the checkpoint resets to the cold init so the next
    fit recovers instead of replaying the poisoned trajectory forever."""
    x = np.tile(np.array([[0.5, 0.5]]), (6, 1))
    y = np.full(6, 1e30)  # f32 overflow: first MLL evaluation is non-finite
    gp = GaussianProcessBandit(dim=2)
    poisoned = {"log_amp": 4.0, "log_ell": [-4.6, -4.6], "log_noise": -9.0}
    zeros = {"log_amp": 0.0, "log_ell": [0.0, 0.0], "log_noise": 0.0}
    gp.fit(x, y, init={"raw": poisoned, "adam_m": zeros, "adam_v": zeros,
                       "adam_t": 60})
    info = gp.last_fit
    assert info.diverged and info.warm
    # the persisted trajectory is the cold init with cold moments, not the
    # poisoned restore point
    assert float(np.asarray(info.raw["log_amp"])) == 0.0
    assert np.allclose(np.asarray(info.raw["log_ell"]), np.log(0.3))
    assert info.t == 0
    assert not np.any(np.asarray(info.m["log_ell"]))


def test_corrupt_init_is_rejected_before_fit():
    """A state blob that passes JSON decode but carries hostile values must
    be screened out by load_state (finite-ness), not crash the fit."""
    md = Metadata()
    bad = json.loads(_example_state(dim=3, num_trials=8).to_value())
    bad["raw"]["log_ell"] = [1e400, 0.1, 0.2]  # json inf
    md.abs_ns(Namespace(GP_BANDIT_NAMESPACE))[STATE_KEY] = json.dumps(bad)
    assert load_state(md, dim=3, num_trials=9) is None


# ---------------------------------------------------------------------------
# Through the service: equivalence, persistence, fallback
# ---------------------------------------------------------------------------


def test_warm_vs_cold_suggestions_agree_trial_for_trial():
    """Two identical deterministic studies; one server keeps its persisted
    state (warm path), the other has it wiped before every operation (cold
    path). Suggestions must agree trial-for-trial across rounds."""
    warm_srv = DefaultVizierServer()
    cold_srv = DefaultVizierServer()
    try:
        clients = {}
        for srv in (warm_srv, cold_srv):
            c = VizierClient.load_or_create_study(
                "equiv-state", _gp_config(), client_id="w", target=srv.address)
            _seed_study(c)
            clients[srv] = c
        name = clients[warm_srv].study_name
        for _ in range(3):
            _wipe_state(cold_srv.datastore, name)
            (tw,) = clients[warm_srv].get_suggestions(count=1)
            (tc,) = clients[cold_srv].get_suggestions(count=1)
            assert tw.parameters.as_dict() == tc.parameters.as_dict()
            metric = _objective(tw.parameters.as_dict())
            clients[warm_srv].complete_trial({"obj": metric}, trial_id=tw.id)
            clients[cold_srv].complete_trial({"obj": metric}, trial_id=tc.id)
        # the warm server's latest checkpoint really came from a warm fit...
        assert _stored_state(warm_srv.datastore, name).warm_started
        # ...and the cold server's from a cold one (its state was wiped)
        assert not _stored_state(cold_srv.datastore, name).warm_started
    finally:
        warm_srv.stop()
        cold_srv.stop()


def test_state_persists_in_process_topology():
    server = DefaultVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "inproc-state", _gp_config(), client_id="w", target=server.address)
        _seed_study(c)
        (t1,) = c.get_suggestions(count=1)
        state = _stored_state(server.datastore, c.study_name)
        assert not state.warm_started and state.num_trials == 8
        assert state.version == STATE_SCHEMA_VERSION
        c.complete_trial({"obj": 0.3}, trial_id=t1.id)
        c.get_suggestions(count=1)
        state2 = _stored_state(server.datastore, c.study_name)
        assert state2.warm_started and state2.num_trials == 9
        # the client-side metadata read surfaces the same blob
        md = c.get_study_metadata()
        assert md.abs_ns(Namespace(GP_BANDIT_NAMESPACE)).get(STATE_KEY) is not None
        c.close()
    finally:
        server.stop()


def test_state_roundtrip_remote_topology_zero_extra_frames():
    """Figure-2 split: the warm-start state rides the existing frames — the
    batch response carries the delta out, GetTrialsMulti(include_studies)
    carries it back in. Frame counts prove no UpdateMetadata/GetStudy frame
    is ever spent on it."""
    server = DistributedVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "remote-state", _gp_config(), client_id="w", target=server.address)
        _seed_study(c)
        batch = VizierBatchClient(server.address)
        (trials,) = batch.get_suggestions(
            [{"study_name": c.study_name, "client_id": "w", "count": 1}])
        state = _stored_state(server.datastore, c.study_name)
        assert not state.warm_started  # first fit on this study is cold
        c.complete_trial({"obj": 0.2}, trial_id=trials[0].id)

        server.servicer.reset_method_counts()
        server.pythia_servicer.reset_method_counts()
        (trials2,) = batch.get_suggestions(
            [{"study_name": c.study_name, "client_id": "w", "count": 1}])
        assert len(trials2) == 1
        state2 = _stored_state(server.datastore, c.study_name)
        assert state2.warm_started and state2.num_trials == 9

        pythia_counts = server.pythia_servicer.method_counts()
        api_counts = server.servicer.method_counts()
        assert pythia_counts.get("PythiaBatchSuggest") == 1
        assert api_counts.get("GetTrialsMulti") == 1
        # zero extra frames for state: no per-policy metadata RPC, no config
        # re-fetch, no trial re-fetch
        assert "UpdateMetadata" not in api_counts
        assert "GetStudy" not in api_counts
        assert "ListTrials" not in api_counts
        batch.close()
        c.close()
    finally:
        server.stop()


@pytest.mark.parametrize("blob", [
    b"\x00\xffgarbage-bytes",
    "definitely not json",
    json.dumps({"version": STATE_SCHEMA_VERSION + 7, "algorithm": "gp_bandit"}),
    json.dumps({**json.loads(_example_state(dim=7, num_trials=8,
                                            raw={"log_amp": 0.1,
                                                 "log_ell": [0.1] * 7,
                                                 "log_noise": -2.0},
                                            adam_m={"log_amp": 0.0,
                                                    "log_ell": [0.0] * 7,
                                                    "log_noise": 0.0},
                                            adam_v={"log_amp": 0.0,
                                                    "log_ell": [0.0] * 7,
                                                    "log_noise": 0.0},
                                            ).to_value())}),  # dim skew (7 != 3)
])
def test_corrupt_or_skewed_state_falls_back_to_cold_fit(blob):
    """Fault injection: a hostile/stale blob in the reserved namespace must
    never fail the suggestion operation — the fit falls back cold and the
    blob is overwritten with a fresh valid checkpoint."""
    server = DefaultVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "fallback-state", _gp_config(), client_id="w", target=server.address)
        _seed_study(c)
        delta = MetadataDelta()
        delta.assign(GP_BANDIT_NAMESPACE, STATE_KEY, blob)
        c.update_metadata(delta)  # plant the bad blob through the client API

        (t,) = c.get_suggestions(count=1)  # must not error
        assert t.id >= 1
        state = _stored_state(server.datastore, c.study_name)
        assert not state.warm_started  # fell back to the cold path
        assert state.version == STATE_SCHEMA_VERSION  # fresh valid checkpoint
        c.close()
    finally:
        server.stop()


def test_update_metadata_reports_skipped_dead_trials():
    """A per-trial update naming a dead trial must not fail the whole delta
    (the study half applies) but IS surfaced in the response."""
    server = DefaultVizierServer()
    try:
        c = VizierClient.load_or_create_study(
            "skipped-md", _gp_config(), client_id="w", target=server.address)
        delta = MetadataDelta()
        delta.assign("user.ns", "k", "v")
        delta.assign("user.ns", "k2", "v2", trial_id=9999)  # never existed
        skipped = c.update_metadata(delta)
        assert skipped == [9999]
        assert c.get_study_metadata().abs_ns(Namespace("user.ns")).get("k") == "v"
        c.close()
    finally:
        server.stop()


def test_warm_start_disabled_writes_no_state():
    ds = InMemoryDatastore()
    from repro.core.study import Study

    cfg = _gp_config()
    study = Study(name="owners/o/studies/nostate", study_config=cfg)
    ds.create_study(study)
    for i in range(8):
        x = (i + 1) / 9.0
        t = Trial(parameters={"x": x, "y": 0.5})
        t.complete(Measurement(metrics={"obj": -(x - 0.4) ** 2}))
        ds.create_trial(study.name, t)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter, warm_start=False)
    descriptor = StudyDescriptor(config=cfg, guid=study.name)
    decision = policy.suggest(SuggestRequest(study_descriptor=descriptor, count=1))
    assert decision.metadata.empty()
    md = ds.get_study(study.name).study_config.metadata
    assert GP_BANDIT_NAMESPACE not in {ns.encode() for ns in md.namespaces()}


# ---------------------------------------------------------------------------
# Property-based metadata namespace roundtrips (hypothesis shim)
# ---------------------------------------------------------------------------

_ns_component = st.composite(
    lambda draw: draw(st.text(min_size=0, max_size=8)).replace(":", "_"))
_namespace = st.composite(
    lambda draw: ":".join(draw(st.lists(_ns_component(), min_size=0, max_size=3))))
_key = st.text(min_size=1, max_size=12)
_small_value = st.one_of(
    st.text(min_size=0, max_size=30),
    st.composite(lambda draw: draw(st.text(min_size=0, max_size=30)).encode())(),
)
# oversized values: tens of KiB, both str and bytes
_big_value = st.composite(
    lambda draw: draw(st.text(min_size=8, max_size=64))
    * draw(st.integers(min_value=256, max_value=2048)))
_value = st.one_of(_small_value, _big_value())


@settings(max_examples=40)
@given(ns=_namespace(), key=_key, value=_value)
def test_metadata_namespace_get_set_roundtrip_property(ns, key, value):
    md = Metadata()
    md.abs_ns(Namespace(ns))[key] = value
    assert md.abs_ns(Namespace(ns))[key] == value
    assert key in md.abs_ns(Namespace(ns))
    back = Metadata.from_proto(md.to_proto())
    assert back == md
    assert back.abs_ns(Namespace(ns)).get(key) == value


@settings(max_examples=40)
@given(entries=st.lists(
    st.tuples(_namespace(), _key, _small_value,
              st.one_of(st.sampled_from([None]), st.integers(1, 5))),
    min_size=0, max_size=8))
def test_metadata_delta_merge_roundtrip_property(entries):
    """assign() + to_proto/from_proto + attach == last-wins merge, for both
    study-level and per-trial updates."""
    delta = MetadataDelta()
    expect_study, expect_trial = {}, {}
    for ns, key, value, trial_id in entries:
        delta.assign(ns, key, value, trial_id=trial_id)
        if trial_id is None:
            expect_study[(ns, key)] = value
        else:
            expect_trial[(trial_id, ns, key)] = value
    assert delta.empty() == (not expect_study and not expect_trial)
    back = MetadataDelta.from_proto(delta.to_proto())
    merged = Metadata()
    merged.attach(back.on_study)
    for (ns, key), value in expect_study.items():
        assert merged.abs_ns(Namespace(ns)).get(key) == value
    for (trial_id, ns, key), value in expect_trial.items():
        assert back.on_trials[trial_id].abs_ns(Namespace(ns)).get(key) == value


def test_update_metadata_rpc_roundtrip_property():
    """Unicode keys and empty/oversized values survive the full wire path:
    UpdateMetadata over a real socket, msgpack framing, datastore, GetStudy."""
    server = DefaultVizierServer()
    counter = itertools.count()
    try:
        @settings(max_examples=15)
        @given(ns=_namespace(), key=_key, value=_value)
        def prop(ns, key, value):
            c = VizierClient.load_or_create_study(
                f"md-prop-{next(counter)}", _gp_config(), client_id="w",
                target=server.address)
            delta = MetadataDelta()
            delta.assign(ns, key, value)
            c.update_metadata(delta)
            back = c.get_study_metadata()
            assert back.abs_ns(Namespace(ns)).get(key) == value
            c.close()

        prop()
    finally:
        server.stop()
