"""Factorized-posterior acquisition engine (repro.pythia.posterior).

Pins the PR's acceptance criteria: cached-posterior and rank-1-updated
scores match the ``ucb_reference`` per-candidate oracle to <= 1e-4, batch
suggestions agree trial-for-trial with the pre-engine path, and the jitted
engine kernels compile at most once across 20 shape-varying suggest
operations (bucket padding kills the per-(n, m) retraces).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis wheel; see shim docstring
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import Measurement, ScaleType, StudyConfig, Trial, TrialState
from repro.core.study import Study
from repro.pythia import posterior as post_mod
from repro.pythia.gp_bandit import GaussianProcessBandit, GPBanditPolicy
from repro.pythia.policy import StudyDescriptor, SuggestRequest
from repro.pythia.posterior import (
    CholeskyPosterior,
    TRACE_COUNTS,
    pool_bucket,
    reset_trace_counts,
    train_bucket,
)
from repro.pythia.supporter import DatastorePolicySupporter
from repro.service.datastore import InMemoryDatastore


def _fitted_gp(n=18, d=3, seed=0, fit_steps=30):
    rng = np.random.RandomState(seed)
    gp = GaussianProcessBandit(dim=d, fit_steps=fit_steps)
    x = rng.rand(n, d)
    y = np.sin(2 * x.sum(axis=1)) + 0.05 * rng.randn(n)
    raw = gp.fit(x, y)
    return gp, raw, x, y


def _raw_tree(d, rng):
    return {
        "log_amp": np.float32(rng.uniform(-0.5, 0.5)),
        "log_ell": np.full((d,), np.log(0.3) + rng.uniform(-0.3, 0.3),
                           np.float32),
        "log_noise": np.float32(rng.uniform(-6.0, -3.0)),
    }


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_bucket_rules():
    assert train_bucket(1) == 64 and train_bucket(64) == 64
    assert train_bucket(65) == 128 and train_bucket(300) == 512
    assert pool_bucket(1) == 256 and pool_bucket(256) == 256
    assert pool_bucket(257) == 512 and pool_bucket(2500) == 2560


# ---------------------------------------------------------------------------
# cached posterior == per-candidate oracle (acceptance: <= 1e-4)
# ---------------------------------------------------------------------------


def test_cached_posterior_matches_ucb_reference_oracle():
    gp, raw, x, y = _fitted_gp()
    rng = np.random.RandomState(1)
    pool = rng.rand(60, x.shape[1])
    post = CholeskyPosterior(raw, x, y)
    post.set_pool(pool)
    oracle = gp.ucb_reference(raw, x, y, pool)
    np.testing.assert_allclose(post.pool_ucb(gp.ucb_beta), oracle,
                               atol=1e-4, rtol=1e-4)


def test_rank1_updated_scores_match_refactorized_oracle():
    """After k rank-1 appends the cached pool scores equal the oracle run
    on the fully refactorized augmented design (acceptance: <= 1e-4)."""
    gp, raw, x, y = _fitted_gp()
    rng = np.random.RandomState(2)
    pool = rng.rand(50, x.shape[1])
    post = CholeskyPosterior(raw, x, y, capacity=len(x) + 6)
    post.set_pool(pool)
    adds_x = rng.rand(6, x.shape[1])
    adds_y = 0.3 * rng.randn(6)
    for ax, ay in zip(adds_x, adds_y):
        post.append(ax, ay)
    x_aug = np.vstack([x, adds_x])
    y_aug = np.concatenate([y, adds_y])
    oracle = gp.ucb_reference(raw, x_aug, y_aug, pool)
    np.testing.assert_allclose(post.pool_ucb(gp.ucb_beta), oracle,
                               atol=1e-4, rtol=1e-4)
    # point queries reuse the same factor
    qm, qs = post.query(pool[:7])
    np.testing.assert_allclose(qm + gp.ucb_beta * qs, oracle[:7],
                               atol=1e-4, rtol=1e-4)


@given(st.integers(min_value=3, max_value=24),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=15, deadline=None)
def test_cholupdate_equals_full_refactorization_property(n, d, k, seed):
    """Property: a chain of rank-1 appends == one fresh factorization of the
    full design, for random sizes/hyperparameters/data."""
    rng = np.random.RandomState(seed)
    raw = _raw_tree(d, rng)
    x = rng.rand(n, d).astype(np.float32)
    y = rng.randn(n).astype(np.float32)
    adds_x = rng.rand(k, d).astype(np.float32)
    adds_y = rng.randn(k).astype(np.float32)

    incremental = CholeskyPosterior(raw, x, y, capacity=n + k)
    for ax, ay in zip(adds_x, adds_y):
        incremental.append(ax, ay)
    fresh = CholeskyPosterior(raw, np.vstack([x, adds_x]),
                              np.concatenate([y, adds_y]))
    xq = rng.rand(20, d).astype(np.float32)
    m_inc, s_inc = incremental.query(xq)
    m_new, s_new = fresh.query(xq)
    np.testing.assert_allclose(m_inc, m_new, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_inc, s_new, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(incremental.alpha)[:n + k],
                               np.asarray(fresh.alpha)[:n + k],
                               atol=5e-3, rtol=5e-3)


def test_exact_duplicate_append_matches_fresh_factorization():
    """Regression: appending a point identical to an existing design row
    drove the new Cholesky pivot to the sqrt(1e-10) numerical floor, so the
    whitened observation exploded and every later query/score was corrupt.
    The pivot is now floored at the noise variance (the true Schur
    complement of a duplicate row is ~2*noise), so a duplicate append must
    agree with a fresh factorization of the augmented design."""
    gp, raw, x, y = _fitted_gp(n=12, d=3)
    rng = np.random.RandomState(5)
    dup_x, dup_y = x[4].copy(), float(y[4])

    incremental = CholeskyPosterior(raw, x, y, capacity=x.shape[0] + 1)
    incremental.append(dup_x, dup_y)
    fresh = CholeskyPosterior(raw, np.vstack([x, dup_x[None]]),
                              np.concatenate([y, [dup_y]]))
    xq = rng.rand(30, 3)
    m_inc, s_inc = incremental.query(xq)
    m_new, s_new = fresh.query(xq)
    assert np.isfinite(m_inc).all() and np.isfinite(s_inc).all()
    np.testing.assert_allclose(m_inc, m_new, atol=5e-3, rtol=5e-3)
    np.testing.assert_allclose(s_inc, s_new, atol=5e-3, rtol=5e-3)

    # pool scores survive the duplicate too (this is what the batch loop
    # consumes right after fantasizing a pending/picked member)
    incremental2 = CholeskyPosterior(raw, x, y, capacity=x.shape[0] + 1)
    incremental2.set_pool(xq)
    incremental2.append(dup_x, dup_y)
    fresh.set_pool(xq)
    np.testing.assert_allclose(incremental2.pool_ucb(1.8), fresh.pool_ucb(1.8),
                               atol=5e-3, rtol=5e-3)


def test_append_past_capacity_refuses():
    gp, raw, x, y = _fitted_gp(n=5, d=2)
    post = CholeskyPosterior(raw, x, y, capacity=6)
    assert post.capacity == 64  # bucket floor
    post.n = post.capacity  # simulate a full buffer
    with pytest.raises(ValueError, match="capacity"):
        post.append(np.zeros(2), 0.0)


# ---------------------------------------------------------------------------
# retrace regression: bucket padding pins <= 1 compile per kernel
# ---------------------------------------------------------------------------


def _study_with_trials(n):
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("a", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("b", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    root.add_float_param("c", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("y", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    ds = InMemoryDatastore()
    study = Study(name=f"owners/o/studies/retrace-{n}", study_config=cfg)
    ds.create_study(study)
    rng = np.random.RandomState(7)
    for _ in range(n):
        a, b, c = rng.rand(3)
        t = Trial(parameters={"a": a, "b": b, "c": c})
        t.complete(Measurement(
            metrics={"y": -(a - 0.4) ** 2 - (b - 0.6) ** 2 - c * 0.1}))
        ds.create_trial(study.name, t)
    return cfg, ds, study


def test_engine_kernels_do_not_retrace_across_20_varying_ops():
    """20 suggest ops at 20 different trial counts (and mixed batch counts)
    inside one shape bucket: every engine kernel compiles at most once.
    Before the engine, each distinct (n_trials, pool_size) retraced the
    jitted acquisition."""
    # warm the jit caches at the bucket the loop will use, then count
    cfg, ds, study = _study_with_trials(33)
    supporter = DatastorePolicySupporter(ds, study.name)
    policy = GPBanditPolicy(supporter, n_candidates=120, min_completed=4,
                            warm_start=False)
    req = SuggestRequest(
        study_descriptor=StudyDescriptor(config=cfg, guid=study.name), count=1)
    policy.suggest(req)

    reset_trace_counts()
    rng = np.random.RandomState(3)
    for op in range(20):  # trial counts 34..53, counts alternate 1/8
        a, b, c = rng.rand(3)
        t = Trial(parameters={"a": a, "b": b, "c": c})
        t.complete(Measurement(metrics={"y": -(a - 0.4) ** 2}))
        ds.create_trial(study.name, t)
        req = SuggestRequest(
            study_descriptor=StudyDescriptor(config=cfg, guid=study.name),
            count=1 if op % 2 else 8)
        decision = policy.suggest(req)
        assert len(decision.suggestions) == (1 if op % 2 else 8)
    assert all(v <= 1 for v in TRACE_COUNTS.values()), dict(TRACE_COUNTS)


def test_trace_counters_tick_on_fresh_shapes():
    """Sanity for the counter itself: a never-seen bucket does retrace (the
    regression test above is not vacuously green)."""
    rng = np.random.RandomState(0)
    d = 7  # dimension unused anywhere else in the suite
    raw = _raw_tree(d, rng)
    reset_trace_counts()
    post = CholeskyPosterior(raw, rng.rand(10, d), rng.randn(10))
    post.set_pool(rng.rand(30, d))
    assert TRACE_COUNTS["factor"] == 1
    assert TRACE_COUNTS["attach_pool"] == 1


# ---------------------------------------------------------------------------
# engine == pre-engine path, trial for trial (acceptance)
# ---------------------------------------------------------------------------


def _suggest(policy, cfg, study, count):
    return policy.suggest(SuggestRequest(
        study_descriptor=StudyDescriptor(config=cfg, guid=study.name),
        count=count)).suggestions


@pytest.mark.parametrize("count,with_pending", [(1, False), (8, False),
                                                (4, True)])
def test_engine_agrees_with_pre_engine_path_trial_for_trial(count,
                                                            with_pending):
    cfg, ds, study = _study_with_trials(14)
    if with_pending:
        rng = np.random.RandomState(11)
        for _ in range(2):
            a, b, c = rng.rand(3)
            t = Trial(parameters={"a": a, "b": b, "c": c})
            t.state = TrialState.ACTIVE
            ds.create_trial(study.name, t)
    supporter = DatastorePolicySupporter(ds, study.name)
    # warm_start off: both paths must run the identical deterministic fit
    engine = GPBanditPolicy(supporter, n_candidates=300, min_completed=4,
                            warm_start=False, use_engine=True)
    legacy = GPBanditPolicy(supporter, n_candidates=300, min_completed=4,
                            warm_start=False, use_engine=False)
    got = _suggest(engine, cfg, study, count)
    want = _suggest(legacy, cfg, study, count)
    assert len(got) == len(want) == count
    for s_eng, s_leg in zip(got, want):
        assert s_eng.parameters.as_dict() == s_leg.parameters.as_dict()
