"""Serving example: batched decode with continuous slot refill.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import DecodeEngine, Request


def main():
    cfg = get_arch("zamba2_1p2b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = DecodeEngine(model, params, batch_size=4, max_seq=64)
    for uid in range(8):  # 8 requests through 4 slots -> continuous batching
        engine.submit(Request(uid=uid, prompt=[1 + uid % 5, 2, 3],
                              max_new_tokens=8))
    done = engine.run_until_done()
    for req in sorted(done, key=lambda r: r.uid):
        print(f"request {req.uid}: prompt={req.prompt} -> {req.output}")
    assert len(done) == 8
    print("served 8 requests through 4 decode slots ✓")


if __name__ == "__main__":
    main()
