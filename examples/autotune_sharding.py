"""shardtune: Vizier optimizes the framework's own sharding/remat config
against the dry-run roofline (beyond-paper integration).

Full-scale runs go through the 512-device dryrun entrypoint; this example
runs the loop itself on a small in-process mesh so it completes on CPU:

    PYTHONPATH=src python examples/autotune_sharding.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import TrialState
from repro.service import DefaultVizierServer, VizierClient
from repro.tuning import shardtune_study_config


def fake_roofline(params) -> float:
    """Stands in for tuning.evaluate_cell (which needs the 512-dev process).
    Shape mirrors reality: remat trades memory for compute; chunk sizes trade
    memory for collective efficiency."""
    remat = params["remat"].as_str
    moe_chunks = params["moe_chunks"].as_float
    qc = params["attn_q_chunk"].as_float
    mb = params["num_microbatches"].as_float
    compute = 0.3 * {"none": 1.0, "block": 1.33, "full": 1.6}[remat]
    memory = 0.5 * {"none": 3.0, "block": 1.0, "full": 0.7}[remat] / mb
    collective = 0.4 * (1 + 0.08 * moe_chunks) * (1024.0 / qc) ** 0.25 * mb**0.15
    return max(compute, memory, collective)


def main():
    server = DefaultVizierServer()
    config = shardtune_study_config()
    client = VizierClient.load_or_create_study(
        "shardtune-demo", config, client_id="tuner", target=server.address)

    for _ in range(20):
        suggestions = client.get_suggestions(count=1)
        if not suggestions:
            break
        trial = suggestions[0]
        step_time = fake_roofline(trial.parameters)
        client.complete_trial({"step_time_s": step_time}, trial_id=trial.id)

    trials = client.list_trials(states=[TrialState.COMPLETED])
    best = min(trials, key=lambda t: t.final_objective("step_time_s"))
    print(f"explored {len(trials)} configs; best step_time="
          f"{best.final_objective('step_time_s'):.4f}s with "
          f"{best.parameters.as_dict()}")
    server.stop()


if __name__ == "__main__":
    main()
