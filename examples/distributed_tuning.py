"""Distributed fault-tolerant tuning (paper §5): parallel workers train real
(reduced) JAX models, stream learning curves, survive crashes, share a study.

Demonstrates, end to end:
  * N parallel TuningWorkers on one study (parallel trials);
  * a worker "crash" mid-trial + restart with the same client_id -> the
    service re-issues the SAME trial (client-side fault tolerance);
  * median automated stopping on learning curves;
  * the separate-Pythia-service topology (paper Figure 2);
  * batched suggestions: one BatchSuggestTrials RPC drives many
    (study, client) pairs through a single coalesced Pythia dispatch.

    PYTHONPATH=src python examples/distributed_tuning.py
"""

import sys
import threading

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.core import AutomatedStoppingConfig, ScaleType, StudyConfig, TrialState
from repro.service import DistributedVizierServer, VizierBatchClient, VizierClient
from repro.train.data import DataConfig
from repro.tuning import TuningTask, TuningWorker


def make_study_config() -> StudyConfig:
    config = StudyConfig()
    root = config.search_space.select_root()
    root.add_float_param("peak_lr", 1e-4, 3e-2, scale_type=ScaleType.LOG)
    root.add_float_param("weight_decay", 0.0, 0.3)
    config.metrics.add("loss", goal="MINIMIZE")
    config.algorithm = "GP_UCB"
    config.automated_stopping = (
        AutomatedStoppingConfig.median_automated_stopping_config(
            min_completed_trials=2))
    return config


def main():
    server = DistributedVizierServer()  # API service + separate Pythia service
    print(f"API server: {server.address}; Pythia server: {server.pythia_address}")

    arch = get_arch("phi4_mini_3p8b", reduced=True)
    task = TuningTask(
        arch=arch,
        data=DataConfig(vocab_size=arch.vocab_size, seq_len=64, global_batch=8),
        total_steps=30,
        report_every=5,
    )

    client = VizierClient.load_or_create_study(
        "lm-tuning", make_study_config(), client_id="admin",
        target=server.address)

    # --- fault-tolerance demo: worker pulls a trial then "crashes" ----------
    w0 = TuningWorker(server.address, client.study_name, "worker_0", task)
    (trial_before,) = w0.client.get_suggestions(count=1)
    print(f"worker_0 got trial {trial_before.id}, then crashes mid-evaluation...")
    del w0  # crash: no CompleteTrial ever sent

    w0b = TuningWorker(server.address, client.study_name, "worker_0", task)
    (trial_after,) = w0b.client.get_suggestions(count=1)
    assert trial_after.id == trial_before.id, "client_id rebind failed!"
    print(f"restarted worker_0 got the SAME trial {trial_after.id} back ✓")

    # --- parallel workers ----------------------------------------------------
    workers = [w0b] + [
        TuningWorker(server.address, client.study_name, f"worker_{i}", task)
        for i in (1, 2)
    ]
    threads = [threading.Thread(target=w.run, kwargs={"max_trials": 2})
               for w in workers]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    trials = client.list_trials(states=[TrialState.COMPLETED])
    best = client.list_optimal_trials()
    print(f"\ncompleted {len(trials)} trials across 3 workers")
    for t in sorted(trials, key=lambda t: t.id):
        print(f"  trial {t.id} [{t.client_id}]: "
              f"lr={t.parameters['peak_lr'].as_float:.5f} "
              f"-> loss {t.final_objective('loss'):.4f} "
              f"({len(t.measurements)} intermediate reports)")
    if best:
        print(f"best: trial {best[0].id} loss={best[0].final_objective('loss'):.4f}")

    # --- batched suggestions -------------------------------------------------
    # A scheduler coordinating many workers (or many studies) can ask the
    # server to coalesce all of their suggestion work into ONE Pythia
    # dispatch: one RPC out, one policy invocation per study with the summed
    # count, pipelined operation polling back. Same protocol semantics as N
    # individual SuggestTrials calls (client_id binding included) at a
    # fraction of the round trips — see benchmarks/service_throughput.py
    # --batched for suggestions/sec at 1/8/64 concurrent clients.
    batch = VizierBatchClient(server.address)
    per_worker = batch.get_suggestions([
        {"study_name": client.study_name, "client_id": f"batch_w{i}", "count": 1}
        for i in range(4)
    ])
    print(f"\nbatched: 1 RPC -> {sum(len(r) for r in per_worker)} trials "
          f"across {len(per_worker)} workers "
          f"(ids {[t.id for r in per_worker for t in r]})")
    batch.complete_trials([
        {"trial_name": f"{client.study_name}/trials/{r[0].id}",
         "metrics": {"loss": 1.0 + 0.1 * i}}
        for i, r in enumerate(per_worker)
    ])
    print("batched: all 4 evaluations reported in one BatchCompleteTrials RPC")
    batch.close()
    server.stop()


if __name__ == "__main__":
    main()
