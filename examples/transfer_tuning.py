"""Transfer learning across studies (stacked residual GP).

A finished study's completed trials warm a NEW study on a related objective:
list the finished study in ``prior_studies`` and the GP-bandit fits one base
GP per prior study — each on the residuals of the stack so far — with the
current study's GP on top, so the very first suggestions already exploit the
prior landscape instead of sampling blind.

    PYTHONPATH=src python examples/transfer_tuning.py
"""

from repro.core import ScaleType, StudyConfig
from repro.service import DefaultVizierServer, VizierClient


def make_config() -> StudyConfig:
    cfg = StudyConfig()
    root = cfg.search_space.select_root()
    root.add_float_param("lr", 1e-4, 1e-1, scale_type=ScaleType.LOG)
    root.add_float_param("momentum", 0.0, 1.0, scale_type=ScaleType.LINEAR)
    cfg.metrics.add("val_acc", "MAXIMIZE")
    cfg.algorithm = "GP_UCB"
    return cfg


def evaluate(params, *, lr_opt: float) -> float:
    """Toy objective family: a peaked response surface whose optimal learning
    rate differs between the prior task and the new task."""
    import math

    lr, mom = float(params["lr"]), float(params["momentum"])
    return -((math.log10(lr) - math.log10(lr_opt)) ** 2) - (mom - 0.9) ** 2


def main() -> None:
    server = DefaultVizierServer()

    # 1. An earlier tuning run on a related task (e.g. the smaller model).
    prior = VizierClient.load_or_create_study(
        "resnet-small", make_config(), client_id="w0", target=server.address)
    for _ in range(20):
        (trial,) = prior.get_suggestions(count=1)
        prior.complete_trial(
            {"val_acc": evaluate(trial.parameters.as_dict(), lr_opt=3e-3)},
            trial_id=trial.id)

    # 2. The new study names the finished one in prior_studies; its trials
    #    ride the same wire frames the suggest already pays for.
    client = VizierClient.load_or_create_study(
        "resnet-large", make_config(), client_id="w0", target=server.address,
        prior_studies=[prior.study_name])
    best = float("-inf")
    for i in range(8):
        (trial,) = client.get_suggestions(count=1)
        acc = evaluate(trial.parameters.as_dict(), lr_opt=5e-3)  # shifted task
        client.complete_trial({"val_acc": acc}, trial_id=trial.id)
        best = max(best, acc)
        print(f"trial {i + 1}: val_acc={acc:+.4f}  best={best:+.4f}")

    for t in client.list_optimal_trials():
        print("optimal:", t.parameters.as_dict())
    prior.close()
    client.close()
    server.stop()


if __name__ == "__main__":
    main()
