"""Multi-metric (Pareto) tuning: accuracy vs latency with the GP bandit.

Multi-objective studies are first-class in the DEFAULT policy: one GP per
metric is fitted on the shared engine buckets and suggestions maximize a
hypervolume-scalarized UCB, so the suggested trials spread ALONG the
accuracy/latency trade-off curve instead of collapsing onto one corner.
The server's ListOptimalTrials returns the observed Pareto frontier, and
the client can score it as a hypervolume number for progress tracking.

    PYTHONPATH=src python examples/multimetric_tuning.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import ScaleType, StudyConfig
from repro.service import DefaultVizierServer, VizierClient


def evaluate(params) -> dict:
    """Stands in for a train-and-benchmark run. Wider nets are more accurate
    but slower; higher learning rates help up to a point."""
    width = params["width"].as_float
    lr = params["lr"].as_float
    accuracy = (width / 1024.0) ** 0.3 * (1.0 - 8.0 * (lr - 0.02) ** 2)
    latency_ms = 1.5 + (width / 64.0) ** 1.4
    return {"accuracy": accuracy, "latency_ms": latency_ms}


def main():
    config = StudyConfig()
    root = config.search_space.select_root()
    root.add_float_param("width", 64, 1024, scale_type=ScaleType.LOG)
    root.add_float_param("lr", 1e-3, 1e-1, scale_type=ScaleType.LOG)
    config.metrics.add("accuracy", "MAXIMIZE")
    config.metrics.add("latency_ms", "MINIMIZE")

    server = DefaultVizierServer()
    client = VizierClient.load_or_create_study(
        "pareto-demo", config, client_id="tuner", target=server.address)

    for _ in range(30):
        (trial,) = client.get_suggestions(count=1)
        client.complete_trial(evaluate(trial.parameters), trial_id=trial.id)

    frontier, vectors = client.pareto_frontier()
    print(f"Pareto frontier: {len(frontier)} of 30 trials "
          f"(hypervolume {client.hypervolume():.3f})")
    for trial, (acc, neg_lat) in sorted(zip(frontier, vectors),
                                        key=lambda p: -p[1][0]):
        # MINIMIZE metrics arrive sign-flipped (larger-is-better convention)
        print(f"  width={trial.parameters['width'].as_float:7.1f} "
              f"lr={trial.parameters['lr'].as_float:.4f} "
              f"accuracy={acc:.3f} latency_ms={-neg_lat:.2f}")
    client.close()
    server.stop()


if __name__ == "__main__":
    main()
