"""Quickstart (paper Code Block 1): tune a blackbox function via the service.

    PYTHONPATH=src python examples/quickstart.py
"""

import math
import sys

sys.path.insert(0, "src")

from repro.core import ScaleType, StudyConfig
from repro.service import DefaultVizierServer, VizierClient


def evaluate_trial(params) -> float:
    """Branin-ish objective over (lr, layers) — maximize."""
    lr = params["lr"].as_float
    layers = params["layers"].as_int
    return -(math.log10(lr) + 2.5) ** 2 - 0.1 * (layers - 3) ** 2


def main():
    server = DefaultVizierServer(host="127.0.0.1")

    config = StudyConfig()
    root = config.search_space.select_root()
    root.add_float_param("lr", 1e-4, 1e-1, scale_type=ScaleType.LOG)
    root.add_int_param("layers", 1, 6)
    config.metrics.add("objective", goal="MAXIMIZE")
    config.algorithm = "GP_UCB"

    client = VizierClient.load_or_create_study(
        "quickstart", config, client_id="worker_0", target=server.address)

    for _ in range(15):
        suggestions = client.get_suggestions(count=1)
        if not suggestions:
            break
        for trial in suggestions:
            value = evaluate_trial(trial.parameters)
            client.complete_trial({"objective": value}, trial_id=trial.id)
            print(f"trial {trial.id}: lr={trial.parameters['lr'].as_float:.5f} "
                  f"layers={trial.parameters['layers'].as_int} -> {value:.4f}")

    best = client.list_optimal_trials()[0]
    print(f"\nbest: {best.parameters.as_dict()} -> "
          f"{best.final_objective('objective'):.4f} (optimum ~ 0 at lr=3.16e-3, layers=3)")
    server.stop()


if __name__ == "__main__":
    main()
