"""End-to-end LM training: ~100M-param model, a few hundred steps, with
checkpoint/restart fault tolerance demonstrated mid-run.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

import jax

from repro.configs import get_arch
from repro.distributed.sharding import ShardingCtx, make_rules
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train.data import DataConfig
from repro.train.step import TrainConfig
from repro.train.train_loop import LoopConfig, train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    args = p.parse_args()

    # ~100M params: phi4-family geometry scaled to d=768/12L
    cfg = dataclasses.replace(
        get_arch("phi4_mini_3p8b"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        vocab_size=32000, attn_q_chunk=256, attn_kv_chunk=256, remat="none")
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(model.param_shapes()))
    print(f"arch: {cfg.name}-100m  params={n_params/1e6:.1f}M")

    mesh = make_local_mesh()
    ctx = ShardingCtx(mesh=mesh, rules=make_rules("train"))
    tc = TrainConfig(peak_lr=6e-4, total_steps=args.steps,
                     warmup_steps=args.steps // 10)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=8)

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    half = args.steps // 2

    with jax.set_mesh(mesh):
        # phase 1: train to the midpoint, checkpointing
        r1 = train(model, tc, dc,
                   LoopConfig(total_steps=half, checkpoint_every=25,
                              checkpoint_dir=ckpt_dir, log_every=25),
                   ctx=ctx)
        print(f"phase 1 done at step {r1.final_step}: "
              f"loss {r1.losses[0]:.3f} -> {r1.losses[-1]:.3f}")
        print("simulating node failure + restart (auto-resume from checkpoint)")

        # phase 2: a fresh process would do exactly this — resume and finish
        r2 = train(model, tc, dc,
                   LoopConfig(total_steps=args.steps, checkpoint_every=50,
                              checkpoint_dir=ckpt_dir, log_every=25),
                   ctx=ctx)
        assert r2.resumed_from == r1.final_step, (r2.resumed_from, r1.final_step)
        print(f"phase 2 resumed from {r2.resumed_from}, finished at "
              f"{r2.final_step}: loss -> {r2.losses[-1]:.3f}")
        total_drop = r1.losses[0] - r2.losses[-1]
        print(f"total loss drop: {total_drop:.3f} "
              f"({'LEARNING ✓' if total_drop > 0.3 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
