# Test/CI entry points. PYTHONPATH=src matches the ROADMAP tier-1 command.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast smoke bench-batched

# tier-1: the full suite (what the driver runs)
test:
	$(PY) -m pytest -x -q

# marker split: everything except the heavyweight model/system tests
test-fast:
	$(PY) -m pytest -q -m "not slow"

# collection-only smoke: catches import regressions (e.g. a jax API moving
# out from under launch/mesh.py) in ~1s without running anything
smoke:
	$(PY) -m pytest --collect-only -q

bench-batched:
	PYTHONPATH=.:src $(PY) benchmarks/service_throughput.py --batched
