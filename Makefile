# Test/CI entry points. PYTHONPATH=src matches the ROADMAP tier-1 command.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast smoke test-dist test-dist-witness test-chaos lint-arch cov-service bench-batched bench-remote-pythia bench-warmstart bench-transfer bench-acquisition bench-scaleout bench-multimetric

# tier-1: the full suite (what the driver runs), then the coverage floors
# (repro.service >= 80%, repro.pythia >= 70%, repro.core >= 70%,
# repro.kernels >= 70%; pytest-cov when installed, stdlib-trace fallback
# otherwise)
test: lint-arch
	$(PY) -m pytest -x -q
	$(PY) tools/check_coverage.py --fail-under 80 --pythia-fail-under 70 --core-fail-under 70 --kernels-fail-under 70

# architecture-invariant analyzer (tools/archlint): lock discipline,
# retrace hygiene, schema/namespace rules, error discipline. Exit-code
# clean in <10s; findings must be fixed or carry a reasoned inline disable
lint-arch:
	$(PY) tools/archlint

# distributed-topology tests only (Figure-2 split: real sockets, fault
# injection, cross-process end-to-end) — includes the slow-marked e2e and
# the seeded chaos suite (a chaos schedule IS a distributed-fault scenario)
test-dist:
	$(PY) -m pytest -q -m "dist or chaos"

# the dist fault suite under the runtime lock-order witness: every lock in
# the service tier records its acquisition order and the session fails if
# the witnessed graph has a cycle (conftest.pytest_sessionfinish)
test-dist-witness:
	ARCHLINT_WITNESS=1 $(PY) -m pytest -q -m "dist or chaos"

# seeded chaos-injection + crash-restart durability suite on its own: the
# ~20-schedule sweep over both topologies plus the SIGKILL recovery tests
test-chaos:
	$(PY) -m pytest -q -m chaos

# the service/pythia/core/kernels coverage floors on their own
cov-service:
	$(PY) tools/check_coverage.py --fail-under 80 --pythia-fail-under 70 --core-fail-under 70 --kernels-fail-under 70

# marker split: everything except the heavyweight model/system tests
test-fast:
	$(PY) -m pytest -q -m "not slow"

# collection-only smoke: catches import regressions (e.g. a jax API moving
# out from under launch/mesh.py) in ~1s without running anything
smoke:
	$(PY) -m pytest --collect-only -q
	$(PY) tools/archlint --fast

bench-batched:
	PYTHONPATH=.:src $(PY) benchmarks/service_throughput.py --batched

bench-remote-pythia:
	PYTHONPATH=.:src $(PY) benchmarks/service_throughput.py --remote-pythia

bench-warmstart:
	PYTHONPATH=.:src $(PY) benchmarks/service_throughput.py --warm-start

bench-transfer:
	PYTHONPATH=.:src $(PY) benchmarks/service_throughput.py --transfer

# suggest-op latency: factorized-posterior engine vs the pre-engine path
# (n in {50,300,1000} x count in {1,8}); writes BENCH_acquisition.json
bench-acquisition:
	PYTHONPATH=.:src $(PY) benchmarks/acquisition_latency.py

# scale-out serving tier: worker-pool throughput (1 vs 8 Pythia workers at
# 64/256 clients, floor: >= 2x) + WaitOperation long-poll latency (floor:
# median < the old 20ms first-poll interval); writes BENCH_scaleout.json
bench-scaleout:
	PYTHONPATH=.:src $(PY) benchmarks/scaleout.py

# multi-metric sample efficiency: hypervolume-vs-trials on 2- and 3-metric
# synthetics, GP bandit vs the NSGA-II baseline (floor: GP >= NSGA-II at 50
# trials on both); writes BENCH_multimetric.json
bench-multimetric:
	PYTHONPATH=.:src $(PY) benchmarks/multimetric.py
